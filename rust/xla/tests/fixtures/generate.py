#!/usr/bin/env python3
"""Emit the hand-authored HLO-text fixtures + manifest.json.

These fixtures let CI exercise the full PJRT runtime path — `Runtime::load`
→ `compile` → `execute_b` — through the `rust/xla` interpreter without JAX
or a native XLA build. They implement a *simplified but honestly-trained*
version of the real artifacts in `python/compile/model.py`:

* ``surrogate_predict`` / ``surrogate_train`` are **faithful**: the same
  3-layer ReLU MLP, MSE loss and Adam update as the JAX graphs.
* ``train_step`` / ``eval_step`` keep the real ABI (32/18 inputs, same
  shapes and order) but model **one hidden layer** of the supernet:
  ``logits = relu(x·(w0*p0) + b[0]) * unit[0] · (wo*po) + bo`` with
  softmax cross-entropy and Adam on ``w0``/``b[0]``/``wo``/``bo``.
  Hidden-stack weights (``wh``), BN parameters, dropout, L1 and QAT inputs
  are carried through untouched — enough for the trainer, IMP local
  search and the full micro-pipeline to run with real learning dynamics,
  while keeping the HLO text reviewable by a human.

The emitted text is deliberately the *subset* of the HLO grammar that
`rust/xla/src/parser.rs` documents, with one liberty: binary ops may take
a rank-0 operand directly (the interpreter broadcasts scalars
implicitly), which keeps the Adam blocks ~3x shorter than fully-explicit
HLO. Regenerate with:  python3 generate.py
"""

import json
import os

OUT_DIR = os.path.dirname(os.path.abspath(__file__))

PAD, L, I, O = 128, 8, 24, 5
BATCH, EVAL_BATCH, HP_LEN = 128, 512, 13
SF, SH, SO, SB, SHP_LEN = 72, 128, 6, 256, 6


def shp(dims):
    return "f32[" + ",".join(str(d) for d in dims) + "]"


class Hlo:
    """Tiny emitter: one instruction per line, unique names enforced."""

    def __init__(self):
        self.lines = []
        self.names = set()

    def emit(self, name, dims, op, root=False, dtype="f32"):
        assert name not in self.names, f"duplicate instruction %{name}"
        self.names.add(name)
        s = dtype + "[" + ",".join(str(d) for d in dims) + "]"
        prefix = "ROOT " if root else ""
        self.lines.append(f"  {prefix}%{name} = {s} {op}")
        return "%" + name


def scalar_consts(h, pairs):
    for name, value in pairs:
        h.emit(name, [], f"constant({value})")


def adam(h, tag, p, g, m, v, dims, lr, b1, b2, eps, omb1, omb2, omb1p, omb2p):
    """model.py adam_update: external bias-correction powers.

    Returns (%new_p, %new_m, %new_v). `tag` keeps names unique.
    """
    s = dims
    mb = h.emit(f"mb_{tag}", s, f"multiply({b1}, {m})")
    gs = h.emit(f"gs_{tag}", s, f"multiply({omb1}, {g})")
    nm = h.emit(f"nm_{tag}", s, f"add({mb}, {gs})")
    g2 = h.emit(f"g2_{tag}", s, f"multiply({g}, {g})")
    vb = h.emit(f"vb_{tag}", s, f"multiply({b2}, {v})")
    g2s = h.emit(f"g2s_{tag}", s, f"multiply({omb2}, {g2})")
    nv = h.emit(f"nv_{tag}", s, f"add({vb}, {g2s})")
    mhat = h.emit(f"mhat_{tag}", s, f"divide({nm}, {omb1p})")
    vhat = h.emit(f"vhat_{tag}", s, f"divide({nv}, {omb2p})")
    sq = h.emit(f"sq_{tag}", s, f"sqrt({vhat})")
    den = h.emit(f"den_{tag}", s, f"add({sq}, {eps})")
    step = h.emit(f"step_{tag}", s, f"divide({mhat}, {den})")
    lstep = h.emit(f"lstep_{tag}", s, f"multiply({lr}, {step})")
    newp = h.emit(f"new_{tag}", s, f"subtract({p}, {lstep})")
    return newp, nm, nv


def hp_scalar(h, name, vec, index):
    sl = h.emit(f"{name}_s", [1], f"slice({vec}), slice={{[{index}:{index + 1}]}}")
    return h.emit(name, [], f"reshape({sl})")


REGIONS = """\
%add_f32 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] add(%a, %b)
}

%max_f32 (a: f32[], b: f32[]) -> f32[] {
  %a = f32[] parameter(0)
  %b = f32[] parameter(1)
  ROOT %r = f32[] maximum(%a, %b)
}
"""


def softmax_ce(h, logits, y1h, rows, tag=""):
    """Emit softmax/CE block. Returns (%probs, %loss, %correct)."""
    B = rows
    rowmax = h.emit(
        f"rowmax{tag}", [B], f"reduce({logits}, %neginf), dimensions={{1}}, to_apply=%max_f32"
    )
    rowmaxb = h.emit(f"rowmaxb{tag}", [B, O], f"broadcast({rowmax}), dimensions={{0}}")
    shift = h.emit(f"shift{tag}", [B, O], f"subtract({logits}, {rowmaxb})")
    expv = h.emit(f"expv{tag}", [B, O], f"exponential({shift})")
    esum = h.emit(
        f"esum{tag}", [B], f"reduce({expv}, %zero), dimensions={{1}}, to_apply=%add_f32"
    )
    esumb = h.emit(f"esumb{tag}", [B, O], f"broadcast({esum}), dimensions={{0}}")
    probs = h.emit(f"probs{tag}", [B, O], f"divide({expv}, {esumb})")
    lse = h.emit(f"lse{tag}", [B], f"log({esum})")
    lseb = h.emit(f"lseb{tag}", [B, O], f"broadcast({lse}), dimensions={{0}}")
    logp = h.emit(f"logp{tag}", [B, O], f"subtract({shift}, {lseb})")
    cet = h.emit(f"cet{tag}", [B, O], f"multiply({y1h}, {logp})")
    cesum = h.emit(
        f"cesum{tag}", [], f"reduce({cet}, %zero), dimensions={{0,1}}, to_apply=%add_f32"
    )
    loss = h.emit(f"loss{tag}", [], f"multiply({cesum}, %neg_inv_rows)")
    ismax = h.emit(
        f"ismax{tag}", [B, O], f"compare({logits}, {rowmaxb}), direction=EQ", dtype="pred"
    )
    ismaxf = h.emit(f"ismaxf{tag}", [B, O], f"convert({ismax})")
    hits = h.emit(f"hits{tag}", [B, O], f"multiply({ismaxf}, {y1h})")
    correct = h.emit(
        f"correct{tag}", [], f"reduce({hits}, %zero), dimensions={{0,1}}, to_apply=%add_f32"
    )
    return probs, loss, correct


def supernet_forward(h, B):
    """Shared forward for train_step/eval_step; params already emitted.

    Returns (%a0 preactivation, %u0b unit mask, %h hidden, %wom, %logits).
    """
    w0m = h.emit("w0m", [I, PAD], "multiply(%w0, %p0)")
    z0 = h.emit(
        "z0", [B, PAD], "dot(%x, %w0m), lhs_contracting_dims={1}, rhs_contracting_dims={0}"
    )
    u0s = h.emit("u0_s", [1, PAD], f"slice(%unit), slice={{[0:1], [0:{PAD}]}}")
    u0 = h.emit("u0", [PAD], f"reshape({u0s})")
    u0b = h.emit("u0b", [B, PAD], f"broadcast({u0}), dimensions={{1}}")
    b0s = h.emit("b0_s", [1, PAD], f"slice(%b), slice={{[0:1], [0:{PAD}]}}")
    b0 = h.emit("b0", [PAD], f"reshape({b0s})")
    b0b = h.emit("b0b", [B, PAD], f"broadcast({b0}), dimensions={{1}}")
    a0 = h.emit("a0", [B, PAD], f"add({z0}, {b0b})")
    zb = h.emit("zerosbb", [B, PAD], "broadcast(%zero), dimensions={}")
    r0 = h.emit("r0", [B, PAD], f"maximum({a0}, {zb})")
    hh = h.emit("h", [B, PAD], f"multiply({r0}, {u0b})")
    wom = h.emit("wom", [PAD, O], "multiply(%wo, %po)")
    zl = h.emit(
        "zl", [B, O], f"dot({hh}, {wom}), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}"
    )
    bob = h.emit("bob", [B, O], "broadcast(%bo), dimensions={1}")
    logits = h.emit("logits", [B, O], f"add({zl}, {bob})")
    return a0, u0b, hh, wom, logits


def gen_train_step():
    h = Hlo()
    params = [
        ("w0", [I, PAD]), ("wh", [L - 1, PAD, PAD]), ("b", [L, PAD]),
        ("gamma", [L, PAD]), ("beta", [L, PAD]), ("wo", [PAD, O]), ("bo", [O]),
    ]
    inputs = (
        params
        + [("m_" + n, s) for n, s in params]
        + [("v_" + n, s) for n, s in params]
        + [
            ("unit", [L, PAD]), ("p0", [I, PAD]), ("ph", [L - 1, PAD, PAD]),
            ("po", [PAD, O]), ("gates", [L]), ("act_sel", [3]), ("hp", [HP_LEN]),
            ("run_mean", [L, PAD]), ("run_var", [L, PAD]),
            ("x", [BATCH, I]), ("y1h", [BATCH, O]),
        ]
    )
    for i, (n, s) in enumerate(inputs):
        h.emit(n, s, f"parameter({i})")
    scalar_consts(
        h,
        [
            ("zero", "0"), ("one", "1"), ("neginf", "-inf"),
            ("inv_rows", 1.0 / BATCH), ("neg_inv_rows", -1.0 / BATCH),
        ],
    )
    # hp scalars (layout: rust/src/nn/abi.rs)
    lr = hp_scalar(h, "lr", "%hp", 4)
    b1 = hp_scalar(h, "beta1", "%hp", 6)
    b2 = hp_scalar(h, "beta2", "%hp", 7)
    eps = hp_scalar(h, "eps", "%hp", 8)
    b1p = hp_scalar(h, "b1pow", "%hp", 9)
    b2p = hp_scalar(h, "b2pow", "%hp", 10)
    omb1 = h.emit("omb1", [], f"subtract(%one, {b1})")
    omb2 = h.emit("omb2", [], f"subtract(%one, {b2})")
    omb1p = h.emit("omb1p", [], f"subtract(%one, {b1p})")
    omb2p = h.emit("omb2p", [], f"subtract(%one, {b2p})")

    a0, u0b, hh, wom, logits = supernet_forward(h, BATCH)
    probs, loss, correct = softmax_ce(h, logits, "%y1h", BATCH)

    # backward
    dl0 = h.emit("dl0", [BATCH, O], f"subtract({probs}, %y1h)")
    dlogits = h.emit("dlogits", [BATCH, O], f"multiply({dl0}, %inv_rows)")
    g_wo0 = h.emit(
        "g_wo0", [PAD, O],
        f"dot({hh}, {dlogits}), lhs_contracting_dims={{0}}, rhs_contracting_dims={{0}}",
    )
    g_wo = h.emit("g_wo", [PAD, O], f"multiply({g_wo0}, %po)")
    g_bo = h.emit(
        "g_bo", [O], f"reduce({dlogits}, %zero), dimensions={{0}}, to_apply=%add_f32"
    )
    dh = h.emit(
        "dh", [BATCH, PAD],
        f"dot({dlogits}, {wom}), lhs_contracting_dims={{1}}, rhs_contracting_dims={{1}}",
    )
    rmask = h.emit("rmask", [BATCH, PAD], f"compare({a0}, %zerosbb), direction=GT", dtype="pred")
    rmaskf = h.emit("rmaskf", [BATCH, PAD], f"convert({rmask})")
    dr = h.emit("dr", [BATCH, PAD], f"multiply({dh}, {rmaskf})")
    dz0 = h.emit("dz0", [BATCH, PAD], f"multiply({dr}, {u0b})")
    g_w00 = h.emit(
        "g_w00", [I, PAD],
        f"dot(%x, {dz0}), lhs_contracting_dims={{0}}, rhs_contracting_dims={{0}}",
    )
    g_w0 = h.emit("g_w0", [I, PAD], f"multiply({g_w00}, %p0)")
    g_b0 = h.emit(
        "g_b0", [PAD], f"reduce({dz0}, %zero), dimensions={{0}}, to_apply=%add_f32"
    )

    sc = (lr, b1, b2, eps, omb1, omb2, omb1p, omb2p)
    nw0, nm_w0, nv_w0 = adam(h, "w0", "%w0", g_w0, "%m_w0", "%v_w0", [I, PAD], *sc)
    nw0m = h.emit("new_w0_masked", [I, PAD], f"multiply({nw0}, %p0)")
    nwo, nm_wo, nv_wo = adam(h, "wo", "%wo", g_wo, "%m_wo", "%v_wo", [PAD, O], *sc)
    nwom = h.emit("new_wo_masked", [PAD, O], f"multiply({nwo}, %po)")
    nbo, nm_bo, nv_bo = adam(h, "bo", "%bo", g_bo, "%m_bo", "%v_bo", [O], *sc)
    # bias row 0 of `b` trains too (Adam state rides in m_b/v_b row 0);
    # rows 1.. pass through untouched.
    b0v = "%b0"
    mb0s = h.emit("m_b0_s", [1, PAD], f"slice(%m_b), slice={{[0:1], [0:{PAD}]}}")
    mb0 = h.emit("m_b0", [PAD], f"reshape({mb0s})")
    vb0s = h.emit("v_b0_s", [1, PAD], f"slice(%v_b), slice={{[0:1], [0:{PAD}]}}")
    vb0 = h.emit("v_b0", [PAD], f"reshape({vb0s})")
    nb0, nm_b0, nv_b0 = adam(h, "b0", b0v, g_b0, mb0, vb0, [PAD], *sc)
    brest = h.emit("b_rest", [L - 1, PAD], f"slice(%b), slice={{[1:{L}], [0:{PAD}]}}")
    nb0r = h.emit("new_b0_row", [1, PAD], f"reshape({nb0})")
    nb = h.emit("new_b", [L, PAD], f"concatenate({nb0r}, {brest}), dimensions={{0}}")
    mrest = h.emit("m_b_rest", [L - 1, PAD], f"slice(%m_b), slice={{[1:{L}], [0:{PAD}]}}")
    nmb0r = h.emit("new_m_b0_row", [1, PAD], f"reshape({nm_b0})")
    nmb = h.emit("new_m_b", [L, PAD], f"concatenate({nmb0r}, {mrest}), dimensions={{0}}")
    vrest = h.emit("v_b_rest", [L - 1, PAD], f"slice(%v_b), slice={{[1:{L}], [0:{PAD}]}}")
    nvb0r = h.emit("new_v_b0_row", [1, PAD], f"reshape({nv_b0})")
    nvb = h.emit("new_v_b", [L, PAD], f"concatenate({nvb0r}, {vrest}), dimensions={{0}}")

    outs = [
        nw0m, "%wh", nb, "%gamma", "%beta", nwom, nbo,
        nm_w0, "%m_wh", nmb, "%m_gamma", "%m_beta", nm_wo, nm_bo,
        nv_w0, "%v_wh", nvb, "%v_gamma", "%v_beta", nv_wo, nv_bo,
        loss, correct, "%run_mean", "%run_var",
    ]
    out_shapes = (
        [shp(s) for _, s in params]
        + [shp(s) for _, s in params]
        + [shp(s) for _, s in params]
        + ["f32[]", "f32[]", shp([L, PAD]), shp([L, PAD])]
    )
    tuple_shape = "(" + ", ".join(out_shapes) + ")"
    h.lines.append(f"  ROOT %result = {tuple_shape} tuple({', '.join(outs)})")

    sig = ", ".join(f"{n}: {shp(s)}" for n, s in inputs)
    return (
        "HloModule train_step\n\n"
        + REGIONS
        + "\n"
        + f"ENTRY %main ({sig}) -> {tuple_shape} {{\n"
        + "\n".join(h.lines)
        + "\n}\n"
    )


def gen_eval_step():
    h = Hlo()
    inputs = [
        ("w0", [I, PAD]), ("wh", [L - 1, PAD, PAD]), ("b", [L, PAD]),
        ("gamma", [L, PAD]), ("beta", [L, PAD]), ("wo", [PAD, O]), ("bo", [O]),
        ("unit", [L, PAD]), ("p0", [I, PAD]), ("ph", [L - 1, PAD, PAD]),
        ("po", [PAD, O]), ("gates", [L]), ("act_sel", [3]), ("ehp", [3]),
        ("run_mean", [L, PAD]), ("run_var", [L, PAD]),
        ("x", [EVAL_BATCH, I]), ("y1h", [EVAL_BATCH, O]),
    ]
    for i, (n, s) in enumerate(inputs):
        h.emit(n, s, f"parameter({i})")
    scalar_consts(
        h, [("zero", "0"), ("neginf", "-inf"), ("neg_inv_rows", -1.0 / EVAL_BATCH)]
    )
    _, _, _, _, logits = supernet_forward(h, EVAL_BATCH)
    _, loss, correct = softmax_ce(h, logits, "%y1h", EVAL_BATCH)
    tuple_shape = f"(f32[], f32[], {shp([EVAL_BATCH, O])})"
    h.lines.append(f"  ROOT %result = {tuple_shape} tuple({correct}, {loss}, {logits})")
    sig = ", ".join(f"{n}: {shp(s)}" for n, s in inputs)
    return (
        "HloModule eval_step\n\n"
        + REGIONS
        + "\n"
        + f"ENTRY %main ({sig}) -> {tuple_shape} {{\n"
        + "\n".join(h.lines)
        + "\n}\n"
    )


SUR_PARAMS = [
    ("sw1", [SF, SH]), ("sb1", [SH]), ("sw2", [SH, SH]),
    ("sb2", [SH]), ("sw3", [SH, SO]), ("sb3", [SO]),
]


def sur_forward(h):
    """Forward through the 3-layer ReLU MLP. Returns (%a1, %h1, %a2, %h2, %pred)."""
    z1 = h.emit(
        "z1", [SB, SH], "dot(%x, %sw1), lhs_contracting_dims={1}, rhs_contracting_dims={0}"
    )
    b1b = h.emit("b1b", [SB, SH], "broadcast(%sb1), dimensions={1}")
    a1 = h.emit("a1", [SB, SH], f"add({z1}, {b1b})")
    zh = h.emit("zeros_h", [SB, SH], "broadcast(%zero), dimensions={}")
    h1 = h.emit("h1", [SB, SH], f"maximum({a1}, {zh})")
    z2 = h.emit(
        "z2", [SB, SH], f"dot({h1}, %sw2), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}"
    )
    b2b = h.emit("b2b", [SB, SH], "broadcast(%sb2), dimensions={1}")
    a2 = h.emit("a2", [SB, SH], f"add({z2}, {b2b})")
    h2 = h.emit("h2", [SB, SH], f"maximum({a2}, {zh})")
    z3 = h.emit(
        "z3", [SB, SO], f"dot({h2}, %sw3), lhs_contracting_dims={{1}}, rhs_contracting_dims={{0}}"
    )
    b3b = h.emit("b3b", [SB, SO], "broadcast(%sb3), dimensions={1}")
    pred = h.emit("pred", [SB, SO], f"add({z3}, {b3b})")
    return a1, h1, a2, h2, pred


def gen_surrogate_predict():
    h = Hlo()
    inputs = SUR_PARAMS + [("x", [SB, SF])]
    for i, (n, s) in enumerate(inputs):
        h.emit(n, s, f"parameter({i})")
    scalar_consts(h, [("zero", "0")])
    _, _, _, _, pred = sur_forward(h)
    tuple_shape = f"({shp([SB, SO])})"
    h.lines.append(f"  ROOT %result = {tuple_shape} tuple({pred})")
    sig = ", ".join(f"{n}: {shp(s)}" for n, s in inputs)
    return (
        "HloModule surrogate_predict\n\n"
        + f"ENTRY %main ({sig}) -> {tuple_shape} {{\n"
        + "\n".join(h.lines)
        + "\n}\n"
    )


def gen_surrogate_train():
    h = Hlo()
    inputs = (
        SUR_PARAMS
        + [("m_" + n, s) for n, s in SUR_PARAMS]
        + [("v_" + n, s) for n, s in SUR_PARAMS]
        + [("x", [SB, SF]), ("y", [SB, SO]), ("shp", [SHP_LEN])]
    )
    for i, (n, s) in enumerate(inputs):
        h.emit(n, s, f"parameter({i})")
    n_elems = SB * SO
    scalar_consts(
        h,
        [
            ("zero", "0"), ("one", "1"),
            ("inv_n", 1.0 / n_elems), ("two_inv_n", 2.0 / n_elems),
        ],
    )
    # shp scalars (layout: rust/src/nn/abi.rs SHP_*)
    lr = hp_scalar(h, "lr", "%shp", 0)
    b1 = hp_scalar(h, "beta1", "%shp", 1)
    b2 = hp_scalar(h, "beta2", "%shp", 2)
    eps = hp_scalar(h, "eps", "%shp", 3)
    b1p = hp_scalar(h, "b1pow", "%shp", 4)
    b2p = hp_scalar(h, "b2pow", "%shp", 5)
    omb1 = h.emit("omb1", [], f"subtract(%one, {b1})")
    omb2 = h.emit("omb2", [], f"subtract(%one, {b2})")
    omb1p = h.emit("omb1p", [], f"subtract(%one, {b1p})")
    omb2p = h.emit("omb2p", [], f"subtract(%one, {b2p})")

    a1, h1, a2, h2, pred = sur_forward(h)
    diff = h.emit("diff", [SB, SO], f"subtract({pred}, %y)")
    sqd = h.emit("sqd", [SB, SO], f"multiply({diff}, {diff})")
    sqsum = h.emit(
        "sqsum", [], f"reduce({sqd}, %zero), dimensions={{0,1}}, to_apply=%add_f32"
    )
    loss = h.emit("loss", [], f"multiply({sqsum}, %inv_n)")

    dpred = h.emit("dpred", [SB, SO], f"multiply({diff}, %two_inv_n)")
    g_w3 = h.emit(
        "g_w3", [SH, SO],
        f"dot({h2}, {dpred}), lhs_contracting_dims={{0}}, rhs_contracting_dims={{0}}",
    )
    g_b3 = h.emit(
        "g_b3", [SO], f"reduce({dpred}, %zero), dimensions={{0}}, to_apply=%add_f32"
    )
    dh2 = h.emit(
        "dh2", [SB, SH],
        f"dot({dpred}, %sw3), lhs_contracting_dims={{1}}, rhs_contracting_dims={{1}}",
    )
    m2 = h.emit("m2", [SB, SH], f"compare({a2}, %zeros_h), direction=GT", dtype="pred")
    m2f = h.emit("m2f", [SB, SH], f"convert({m2})")
    dz2 = h.emit("dz2", [SB, SH], f"multiply({dh2}, {m2f})")
    g_w2 = h.emit(
        "g_w2", [SH, SH],
        f"dot({h1}, {dz2}), lhs_contracting_dims={{0}}, rhs_contracting_dims={{0}}",
    )
    g_b2 = h.emit(
        "g_b2", [SH], f"reduce({dz2}, %zero), dimensions={{0}}, to_apply=%add_f32"
    )
    dh1 = h.emit(
        "dh1", [SB, SH],
        f"dot({dz2}, %sw2), lhs_contracting_dims={{1}}, rhs_contracting_dims={{1}}",
    )
    m1 = h.emit("m1", [SB, SH], f"compare({a1}, %zeros_h), direction=GT", dtype="pred")
    m1f = h.emit("m1f", [SB, SH], f"convert({m1})")
    dz1 = h.emit("dz1", [SB, SH], f"multiply({dh1}, {m1f})")
    g_w1 = h.emit(
        "g_w1", [SF, SH],
        f"dot(%x, {dz1}), lhs_contracting_dims={{0}}, rhs_contracting_dims={{0}}",
    )
    g_b1 = h.emit(
        "g_b1", [SH], f"reduce({dz1}, %zero), dimensions={{0}}, to_apply=%add_f32"
    )

    grads = {"sw1": g_w1, "sb1": g_b1, "sw2": g_w2, "sb2": g_b2, "sw3": g_w3, "sb3": g_b3}
    sc = (lr, b1, b2, eps, omb1, omb2, omb1p, omb2p)
    news, newms, newvs = [], [], []
    for name, dims in SUR_PARAMS:
        np_, nm_, nv_ = adam(
            h, name, f"%{name}", grads[name], f"%m_{name}", f"%v_{name}", dims, *sc
        )
        news.append(np_)
        newms.append(nm_)
        newvs.append(nv_)

    outs = news + newms + newvs + [loss]
    out_shapes = [shp(s) for _, s in SUR_PARAMS] * 3 + ["f32[]"]
    tuple_shape = "(" + ", ".join(out_shapes) + ")"
    h.lines.append(f"  ROOT %result = {tuple_shape} tuple({', '.join(outs)})")
    sig = ", ".join(f"{n}: {shp(s)}" for n, s in inputs)
    return (
        "HloModule surrogate_train\n\n"
        + REGIONS
        + "\n"
        + f"ENTRY %main ({sig}) -> {tuple_shape} {{\n"
        + "\n".join(h.lines)
        + "\n}\n"
    )


def gen_manifest():
    def art(file, inputs, outputs):
        return {
            "file": file,
            "inputs": [{"name": n, "shape": s} for n, s in inputs],
            "outputs": outputs,
        }

    params = [
        ("w0", [I, PAD]), ("wh", [L - 1, PAD, PAD]), ("b", [L, PAD]),
        ("gamma", [L, PAD]), ("beta", [L, PAD]), ("wo", [PAD, O]), ("bo", [O]),
    ]
    names = [n for n, _ in params]
    train_inputs = (
        params
        + [("m_" + n, s) for n, s in params]
        + [("v_" + n, s) for n, s in params]
        + [
            ("unit", [L, PAD]), ("p0", [I, PAD]), ("ph", [L - 1, PAD, PAD]),
            ("po", [PAD, O]), ("gates", [L]), ("act_sel", [3]), ("hp", [HP_LEN]),
            ("run_mean", [L, PAD]), ("run_var", [L, PAD]),
            ("x", [BATCH, I]), ("y1h", [BATCH, O]),
        ]
    )
    train_outputs = (
        names + ["m_" + n for n in names] + ["v_" + n for n in names]
        + ["loss", "correct", "run_mean", "run_var"]
    )
    eval_inputs = params + [
        ("unit", [L, PAD]), ("p0", [I, PAD]), ("ph", [L - 1, PAD, PAD]),
        ("po", [PAD, O]), ("gates", [L]), ("act_sel", [3]), ("ehp", [3]),
        ("run_mean", [L, PAD]), ("run_var", [L, PAD]),
        ("x", [EVAL_BATCH, I]), ("y1h", [EVAL_BATCH, O]),
    ]
    sur_names = [n for n, _ in SUR_PARAMS]
    sur_train_inputs = (
        SUR_PARAMS
        + [("m_" + n, s) for n, s in SUR_PARAMS]
        + [("v_" + n, s) for n, s in SUR_PARAMS]
        + [("x", [SB, SF]), ("y", [SB, SO]), ("shp", [SHP_LEN])]
    )
    sur_train_outputs = (
        sur_names + ["m_" + n for n in sur_names] + ["v_" + n for n in sur_names] + ["loss"]
    )
    return {
        "abi_version": 1,
        "generator": "rust/xla/tests/fixtures/generate.py (hand-authored interpreter fixtures)",
        "constants": {
            "pad": PAD, "num_layers": L, "in_dim": I, "out_dim": O,
            "batch": BATCH, "eval_batch": EVAL_BATCH, "hp_len": HP_LEN,
            "sur_feats": SF, "sur_out": SO, "sur_batch": SB,
        },
        "artifacts": {
            "train_step": art("train_step.hlo.txt", train_inputs, train_outputs),
            "eval_step": art("eval_step.hlo.txt", eval_inputs, ["correct", "loss", "logits"]),
            "surrogate_train": art(
                "surrogate_train.hlo.txt", sur_train_inputs, sur_train_outputs
            ),
            "surrogate_predict": art(
                "surrogate_predict.hlo.txt", SUR_PARAMS + [("x", [SB, SF])], ["pred"]
            ),
        },
    }


def main():
    files = {
        "train_step.hlo.txt": gen_train_step(),
        "eval_step.hlo.txt": gen_eval_step(),
        "surrogate_train.hlo.txt": gen_surrogate_train(),
        "surrogate_predict.hlo.txt": gen_surrogate_predict(),
        "manifest.json": json.dumps(gen_manifest(), indent=1) + "\n",
    }
    for name, text in files.items():
        path = os.path.join(OUT_DIR, name)
        with open(path, "w") as f:
            f.write(text)
        print(f"wrote {name} ({len(text.splitlines())} lines)")


if __name__ == "__main__":
    main()
