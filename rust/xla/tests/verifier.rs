//! Mutation harness for the static plan verifier: prove `verify` has
//! teeth by programmatically corrupting valid compiled plans — one
//! mutation per corruption class — and asserting each is rejected with a
//! typed `PlanVerifyError` naming the corrupted instruction, while the
//! uncorrupted plan (and every shipped fixture) verifies clean.
//!
//! The five classes mirror the real failure modes of the compiled-plan
//! layer: an off-by-one stride walking a gather past its operand, a slot
//! freed while later steps still read it, a slot freed twice, a dot row
//! partition that would overrun the output under threading, and an alias
//! pointing at a slot that does not exist.

use std::sync::Arc;

use xla::plan::ExecPlan;
use xla::verify::mutate::{corrupt, Corruption};
use xla::verify::{Invariant, PlanVerifyError};

/// One module exercising every mutation site: a dot (partition), a
/// transpose (gather strides), a reduce region, a reshape (alias chain)
/// and a tuple root.
const HARNESS: &str = "\
HloModule vharness

%add (p0: f32[], p1: f32[]) -> f32[] {
  %p0 = f32[] parameter(0)
  %p1 = f32[] parameter(1)
  ROOT %s = f32[] add(%p0, %p1)
}

ENTRY %main (x: f32[4,3], w: f32[3,5]) -> (f32[5,4], f32[4], f32[20]) {
  %x = f32[4,3]{1,0} parameter(0)
  %w = f32[3,5]{1,0} parameter(1)
  %d = f32[4,5]{1,0} dot(f32[4,3] %x, f32[3,5] %w), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  %t = f32[5,4]{1,0} transpose(f32[4,5] %d), dimensions={1,0}
  %zero = f32[] constant(0)
  %sum = f32[4]{0} reduce(f32[4,3] %x, f32[] %zero), dimensions={1}, to_apply=%add
  %flat = f32[20]{0} reshape(f32[5,4] %t)
  ROOT %out = (f32[5,4], f32[4], f32[20]) tuple(%t, %sum, %flat)
}
";

fn fresh_plan() -> ExecPlan {
    let module = Arc::new(xla::parser::parse_module(HARNESS).expect("parse harness module"));
    ExecPlan::new(module).expect("plan harness module")
}

/// Corrupt a fresh plan with `c` and assert the verifier rejects it with
/// the expected invariant class, naming the corrupted instruction.
fn assert_rejected(c: Corruption, want: Invariant) -> PlanVerifyError {
    let mut plan = fresh_plan();
    plan.verify().expect("uncorrupted plan must verify clean");
    let name = corrupt(&mut plan, c).expect("harness must have an eligible corruption site");
    let err = plan
        .verify()
        .expect_err("corrupted plan must be rejected by verify");
    assert_eq!(
        err.instruction, name,
        "{c:?} must be reported at the corrupted instruction: {err}"
    );
    assert_eq!(err.invariant, want, "{c:?} invariant class: {err}");
    assert!(
        err.to_string().contains(&format!("%{name}")),
        "display must name the instruction: {err}"
    );
    err
}

#[test]
fn off_by_one_stride_is_rejected_as_bounds() {
    let err = assert_rejected(Corruption::GatherStrideOffByOne, Invariant::Bounds);
    assert!(err.detail.contains("out of bounds"), "{err}");
}

#[test]
fn premature_free_is_rejected_as_liveness() {
    let err = assert_rejected(Corruption::PrematureFree, Invariant::Liveness);
    assert!(err.detail.contains("still read by"), "{err}");
}

#[test]
fn double_free_is_rejected_as_liveness() {
    let err = assert_rejected(Corruption::DoubleFree, Invariant::Liveness);
    assert!(err.detail.contains("twice"), "{err}");
}

#[test]
fn overlapping_thread_rows_are_rejected_as_partition() {
    let err = assert_rejected(Corruption::OverlappingThreadRows, Invariant::Partition);
    assert!(err.detail.contains("overlap"), "{err}");
}

#[test]
fn dangling_alias_is_rejected_as_dataflow() {
    let err = assert_rejected(Corruption::DanglingAlias, Invariant::Dataflow);
    assert!(err.detail.contains("not defined"), "{err}");
}

#[test]
fn the_five_corruption_classes_are_distinct() {
    // each class must be distinguishable from the others by its report,
    // not collapse into one generic failure
    let reports: Vec<String> = [
        (Corruption::GatherStrideOffByOne, Invariant::Bounds),
        (Corruption::PrematureFree, Invariant::Liveness),
        (Corruption::DoubleFree, Invariant::Liveness),
        (Corruption::OverlappingThreadRows, Invariant::Partition),
        (Corruption::DanglingAlias, Invariant::Dataflow),
    ]
    .into_iter()
    .map(|(c, want)| assert_rejected(c, want).to_string())
    .collect();
    for (i, a) in reports.iter().enumerate() {
        for b in &reports[i + 1..] {
            assert_ne!(a, b, "two corruption classes produced identical reports");
        }
    }
}

/// Every shipped fixture artifact — the exact modules the search pipeline
/// executes — must verify clean, through the same `compile` entry point
/// production uses (which, in debug/test builds, verifies every plan).
#[test]
fn all_fixture_artifacts_verify_clean() {
    let dir = std::path::Path::new(env!("CARGO_MANIFEST_DIR")).join("tests/fixtures");
    let client = xla::PjRtClient::cpu().expect("client");
    let mut checked = 0usize;
    for entry in std::fs::read_dir(&dir).expect("fixtures dir") {
        let path = entry.expect("dir entry").path();
        if path.extension().and_then(|e| e.to_str()) != Some("txt") {
            continue;
        }
        let proto = xla::HloModuleProto::from_text_file(&path).expect("parse fixture");
        let exe = client
            .compile(&xla::XlaComputation::from_proto(&proto))
            .unwrap_or_else(|e| panic!("{path:?} failed to compile: {e}"));
        exe.verify().unwrap_or_else(|e| panic!("{path:?} failed to verify: {e}"));
        checked += 1;
    }
    assert_eq!(checked, 4, "expected the four fixture artifacts");
}

/// Representative op coverage beyond the fixtures: the differential
/// harness's op mix (broadcast/slice/select/compare/concat/iota/convert,
/// batch dots) all passes the verifier.
#[test]
fn representative_modules_verify_clean() {
    let modules = [
        // batched dot + transpose back
        "HloModule vbatch\n\nENTRY %main (a: f32[2,3,4], b: f32[2,4,5]) -> f32[2,3,5] {\n  \
         %a = f32[2,3,4]{2,1,0} parameter(0)\n  \
         %b = f32[2,4,5]{2,1,0} parameter(1)\n  \
         ROOT %d = f32[2,3,5]{2,1,0} dot(f32[2,3,4] %a, f32[2,4,5] %b), \
         lhs_batch_dims={0}, rhs_batch_dims={0}, \
         lhs_contracting_dims={2}, rhs_contracting_dims={1}\n}\n",
        // strided slice + broadcast + select over a compare
        "HloModule vselect\n\nENTRY %main (x: f32[6,4]) -> f32[3,4] {\n  \
         %x = f32[6,4]{1,0} parameter(0)\n  \
         %s = f32[3,4]{1,0} slice(%x), slice={[0:6:2], [0:4]}\n  \
         %zero = f32[] constant(0)\n  \
         %zb = f32[3,4]{1,0} broadcast(%zero), dimensions={}\n  \
         %m = pred[3,4]{1,0} compare(%s, %zb), direction=GT\n  \
         ROOT %r = f32[3,4]{1,0} select(%m, %s, %zb)\n}\n",
        // iota + convert (dead slot, freed immediately) + concatenate + reduce
        "HloModule vmix\n\n%add (a: f32[], b: f32[]) -> f32[] {\n  \
         %a = f32[] parameter(0)\n  \
         %b = f32[] parameter(1)\n  \
         ROOT %r = f32[] add(%a, %b)\n}\n\n\
         ENTRY %main (x: f32[2,3]) -> f32[] {\n  \
         %x = f32[2,3]{1,0} parameter(0)\n  \
         %i = f32[2,3]{1,0} iota(), iota_dimension=1\n  \
         %ci = s32[2,3]{1,0} convert(%i)\n  \
         %c = f32[4,3]{1,0} concatenate(%x, %i), dimensions={0}\n  \
         %zero = f32[] constant(0)\n  \
         ROOT %s = f32[] reduce(f32[4,3] %c, f32[] %zero), dimensions={0,1}, to_apply=%add\n}\n",
        // zero-size dims flow through gather/dot verification
        "HloModule vzero\n\nENTRY %main (x: f32[0,3]) -> f32[3,0] {\n  \
         %x = f32[0,3]{1,0} parameter(0)\n  \
         ROOT %t = f32[3,0]{1,0} transpose(f32[0,3] %x), dimensions={1,0}\n}\n",
    ];
    for text in modules {
        let module = Arc::new(xla::parser::parse_module(text).expect("parse"));
        let plan = ExecPlan::new(module).expect("plan");
        plan.verify().unwrap_or_else(|e| panic!("{e}\n{text}"));
    }
}
