//! Static verification of compiled execution plans.
//!
//! [`crate::plan`] buys its speed with manually-computed stride/offset
//! tables, last-use liveness and thread-partitioned kernels — exactly the
//! class of logical invariants safe Rust cannot check for us and that, if
//! silently wrong, corrupt every objective the search optimizes. This
//! module proves those invariants per [`ExecPlan`], **without executing
//! anything**:
//!
//! * **Bounds soundness** — for every gather/stride table and kernel
//!   access pattern, the maximal reachable offset
//!   (`base + Σ (dim_i − 1)·stride_i`) lies inside the source buffer,
//!   including zero-size-dim and merged-run edge cases, and every
//!   elementwise/concat/iota/reduce step produces exactly the element
//!   count its output buffer holds.
//! * **Liveness soundness** — the def/last-use schedule frees every arena
//!   slot exactly once, never before a reader, and never the root; alias
//!   chains (reshape/copy/convert/scalar-pred-select refcount bumps) read
//!   their source slot while it is still live.
//! * **Partition soundness** — the multithreaded dot-general row
//!   partitioning ([`kernels::partition_rows`]) covers each output row
//!   exactly once, with no overlap and no gap, at every thread count —
//!   the precondition for the bit-identical `--threads` determinism
//!   contract.
//! * **Dataflow well-formedness** — operands defined before use, tuple
//!   arities match, the root is a real step, and no parameter slot is
//!   dead.
//!
//! Violations surface as a typed [`PlanVerifyError`] naming the
//! offending instruction and the invariant. The verifier runs
//! unconditionally inside `PjRtClient::compile` in debug builds (so every
//! test exercises it) and opt-in in release via [`set_verify_plans`], the
//! `verify_plans` preset key, or `SNAC_XLA_VERIFY=1`.
//!
//! The [`mutate`] hooks let `tests/verifier.rs` prove the verifier has
//! teeth: each corruption class (off-by-one stride, premature free,
//! double free, overlapping thread rows, dangling alias) is applied to a
//! valid plan and must be rejected with an error naming the corrupted
//! instruction.

use std::fmt;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::OnceLock;

use crate::interp::Value;
use crate::kernels;
use crate::parser::ShapeDecl;
use crate::plan::{CompPlan, ExecPlan, EwForm, Step, StepKind};

/// When set (or when `SNAC_XLA_VERIFY=1` is in the environment),
/// `PjRtClient::compile` statically verifies every plan it produces even
/// in release builds. Debug builds always verify.
static FORCE_VERIFY: AtomicBool = AtomicBool::new(false);
static ENV_VERIFY: OnceLock<bool> = OnceLock::new();

/// Force (or stop forcing) plan verification at compile time for this
/// process. Plumbed from the `verify_plans` preset knob.
pub fn set_verify_plans(on: bool) {
    FORCE_VERIFY.store(on, Ordering::Relaxed);
}

/// Whether `PjRtClient::compile` currently verifies compiled plans.
pub fn verify_plans() -> bool {
    cfg!(debug_assertions)
        || FORCE_VERIFY.load(Ordering::Relaxed)
        || *ENV_VERIFY.get_or_init(|| std::env::var("SNAC_XLA_VERIFY").is_ok_and(|v| v == "1"))
}

/// The invariant class a [`PlanVerifyError`] violates.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Invariant {
    /// An offset table or access pattern can reach outside its buffer,
    /// or a step's element accounting disagrees with its output size.
    Bounds,
    /// The free schedule drops a slot too early, twice, never, or drops
    /// the root.
    Liveness,
    /// The dot-general thread partition would not cover each output row
    /// exactly once.
    Partition,
    /// Operand ordering, tuple arity, root or parameter wiring is broken.
    Dataflow,
}

impl fmt::Display for Invariant {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(match self {
            Invariant::Bounds => "bounds",
            Invariant::Liveness => "liveness",
            Invariant::Partition => "partition",
            Invariant::Dataflow => "dataflow",
        })
    }
}

/// A static-verification failure: which instruction, which invariant, and
/// what exactly would have gone wrong at execution time.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PlanVerifyError {
    /// Computation the offending instruction belongs to.
    pub computation: String,
    /// Name of the offending instruction (without the leading `%`).
    pub instruction: String,
    /// Invariant class that failed.
    pub invariant: Invariant,
    /// Human-readable specifics.
    pub detail: String,
}

impl fmt::Display for PlanVerifyError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "plan verification failed [{}] at `%{}` in computation `{}`: {}",
            self.invariant, self.instruction, self.computation, self.detail
        )
    }
}

impl std::error::Error for PlanVerifyError {}

type VResult = std::result::Result<(), PlanVerifyError>;

/// What a slot holds at execution time, as far as sizes are concerned.
#[derive(Debug, Clone)]
enum VKind {
    Arr(usize),
    Tup(Vec<VKind>),
}

fn decl_kind(decl: &ShapeDecl) -> VKind {
    match decl {
        ShapeDecl::Array(s) => VKind::Arr(s.elems()),
        ShapeDecl::Tuple(parts) => VKind::Tup(parts.iter().map(decl_kind).collect()),
    }
}

fn value_kind(v: &Value) -> VKind {
    match v {
        Value::Array(a) => VKind::Arr(a.data.len()),
        Value::Tuple(parts) => VKind::Tup(parts.iter().map(value_kind).collect()),
    }
}

fn table_max(table: &[usize]) -> usize {
    table.iter().copied().max().unwrap_or(0)
}

impl ExecPlan {
    /// Statically prove this plan's bounds, liveness, partition and
    /// dataflow invariants, without executing it. `Ok(())` means every
    /// computation in the module passed every check; the first violation
    /// is returned as a typed [`PlanVerifyError`] naming the instruction.
    pub fn verify(&self) -> VResult {
        for comp in &self.comps {
            let cv = CompVerifier { plan: self, comp };
            cv.verify()?;
        }
        Ok(())
    }
}

struct CompVerifier<'a> {
    plan: &'a ExecPlan,
    comp: &'a CompPlan,
}

impl CompVerifier<'_> {
    fn fail(&self, instruction: &str, invariant: Invariant, detail: String) -> PlanVerifyError {
        PlanVerifyError {
            computation: self.comp.name.clone(),
            instruction: instruction.to_string(),
            invariant,
            detail,
        }
    }

    fn step_name(&self, slot: usize) -> &str {
        self.comp
            .steps
            .get(slot)
            .map(|s| s.name.as_str())
            .unwrap_or("<undefined>")
    }

    fn verify(&self) -> VResult {
        let n = self.comp.steps.len();
        if self.comp.root >= n {
            return Err(self.fail(
                &self.comp.name,
                Invariant::Dataflow,
                format!("root slot {} out of range ({n} steps)", self.comp.root),
            ));
        }
        let mut kinds: Vec<VKind> = Vec::with_capacity(n);
        let mut params_seen = vec![false; self.comp.n_params];
        for (idx, step) in self.comp.steps.iter().enumerate() {
            for o in step.kind.operands() {
                if o >= idx {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Dataflow,
                        format!("operand slot {o} is not defined before this step (index {idx})"),
                    ));
                }
            }
            let kind = self.check_step(idx, step, &kinds, &mut params_seen)?;
            kinds.push(kind);
        }
        if let Some(p) = params_seen.iter().position(|&seen| !seen) {
            return Err(self.fail(
                &self.comp.name,
                Invariant::Dataflow,
                format!("parameter {p} has no defining step (dead parameter slot)"),
            ));
        }
        self.check_liveness()
    }

    /// The free schedule must drop every non-root slot exactly once, at
    /// or after its last reader; the root must outlive the computation.
    fn check_liveness(&self) -> VResult {
        let n = self.comp.steps.len();
        if self.comp.free_after.len() != n {
            return Err(self.fail(
                &self.comp.name,
                Invariant::Liveness,
                format!(
                    "free schedule covers {} steps, plan has {n}",
                    self.comp.free_after.len()
                ),
            ));
        }
        // recompute last use from what each step actually reads, so a
        // corrupted operand and a corrupted free point disagree loudly
        let mut last_use: Vec<usize> = (0..n).collect();
        for (idx, step) in self.comp.steps.iter().enumerate() {
            for o in step.kind.operands() {
                last_use[o] = last_use[o].max(idx);
            }
        }
        let mut freed_at: Vec<Option<usize>> = vec![None; n];
        for (at, dead) in self.comp.free_after.iter().enumerate() {
            for &d in dead {
                if d >= n {
                    return Err(self.fail(
                        self.step_name(at),
                        Invariant::Liveness,
                        format!("free schedule drops undefined slot {d}"),
                    ));
                }
                if let Some(prev) = freed_at[d] {
                    return Err(self.fail(
                        self.step_name(d),
                        Invariant::Liveness,
                        format!(
                            "slot is freed twice: after `%{}` and again after `%{}`",
                            self.step_name(prev),
                            self.step_name(at)
                        ),
                    ));
                }
                freed_at[d] = Some(at);
                if d == self.comp.root {
                    return Err(self.fail(
                        self.step_name(d),
                        Invariant::Liveness,
                        "the root slot must outlive the computation but is freed".to_string(),
                    ));
                }
                if at < d {
                    return Err(self.fail(
                        self.step_name(d),
                        Invariant::Liveness,
                        format!("freed after step {at}, before it is even defined"),
                    ));
                }
                if at < last_use[d] {
                    return Err(self.fail(
                        self.step_name(d),
                        Invariant::Liveness,
                        format!(
                            "freed after `%{}` but still read by `%{}`",
                            self.step_name(at),
                            self.step_name(last_use[d])
                        ),
                    ));
                }
            }
        }
        for (slot, fa) in freed_at.iter().enumerate() {
            if slot != self.comp.root && fa.is_none() {
                return Err(self.fail(
                    self.step_name(slot),
                    Invariant::Liveness,
                    "slot is never freed (arena slot leak)".to_string(),
                ));
            }
        }
        Ok(())
    }

    /// The operand's slot kind, which must be an array; returns its
    /// element count.
    fn arr(
        &self,
        step: &Step,
        kinds: &[VKind],
        o: usize,
        role: &str,
    ) -> Result<usize, PlanVerifyError> {
        match &kinds[o] {
            VKind::Arr(len) => Ok(*len),
            VKind::Tup(_) => Err(self.fail(
                &step.name,
                Invariant::Dataflow,
                format!("{role} operand `%{}` is a tuple, expected an array", self.step_name(o)),
            )),
        }
    }

    /// Per-step checks; returns what the slot will hold.
    fn check_step(
        &self,
        idx: usize,
        step: &Step,
        kinds: &[VKind],
        params_seen: &mut [bool],
    ) -> Result<VKind, PlanVerifyError> {
        match &step.kind {
            StepKind::Parameter(p) => self.check_parameter(idx, step, *p, params_seen),
            StepKind::Constant(value) => Ok(value_kind(value)),
            StepKind::Unary { a, shape, .. } => {
                let na = self.arr(step, kinds, *a, "unary")?;
                self.expect_elems(step, "unary", na, shape.elems())?;
                Ok(VKind::Arr(shape.elems()))
            }
            StepKind::Binary { a, b, form, shape, .. }
            | StepKind::Compare { a, b, form, shape, .. } => {
                let na = self.arr(step, kinds, *a, "lhs")?;
                let nb = self.arr(step, kinds, *b, "rhs")?;
                let out = shape.elems();
                let ok = match form {
                    EwForm::Equal => na == out && nb == out,
                    EwForm::AScalar => na == 1 && nb == out,
                    EwForm::BScalar => nb == 1 && na == out,
                };
                if !ok {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!(
                            "elementwise form {form:?} inconsistent with operand sizes \
                             {na}/{nb} and output size {out}"
                        ),
                    ));
                }
                Ok(VKind::Arr(out))
            }
            StepKind::Select {
                pred,
                on_true,
                on_false,
                pred_scalar,
                shape,
            } => {
                let pp = self.arr(step, kinds, *pred, "predicate")?;
                let pt = self.arr(step, kinds, *on_true, "on-true")?;
                let pf = self.arr(step, kinds, *on_false, "on-false")?;
                let out = shape.elems();
                if pt != out || pf != out {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!("select branches hold {pt}/{pf} elements, output holds {out}"),
                    ));
                }
                let want = if *pred_scalar { 1 } else { out };
                if pp != want {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!("select predicate holds {pp} elements, expected {want}"),
                    ));
                }
                Ok(VKind::Arr(out))
            }
            StepKind::Fill { a, shape } => {
                let na = self.arr(step, kinds, *a, "fill")?;
                if na != 1 {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!("fill source holds {na} elements, expected a scalar"),
                    ));
                }
                Ok(VKind::Arr(shape.elems()))
            }
            StepKind::Gather { a, plan, shape } => {
                let na = self.arr(step, kinds, *a, "gather")?;
                self.check_gather(step, plan, na, shape.elems())?;
                Ok(VKind::Arr(shape.elems()))
            }
            StepKind::Alias { a, shape } => {
                let na = self.arr(step, kinds, *a, "alias")?;
                self.expect_elems(step, "alias", na, shape.elems())?;
                Ok(VKind::Arr(shape.elems()))
            }
            StepKind::ConvertInt { a, shape } | StepKind::ConvertPred { a, shape } => {
                let na = self.arr(step, kinds, *a, "convert")?;
                self.expect_elems(step, "convert", na, shape.elems())?;
                Ok(VKind::Arr(shape.elems()))
            }
            StepKind::Concat {
                parts,
                chunks,
                outer,
                shape,
            } => {
                if parts.len() != chunks.len() {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!("{} parts but {} chunk sizes", parts.len(), chunks.len()),
                    ));
                }
                let per_outer: usize = chunks.iter().sum();
                self.expect_elems(step, "concatenate", outer * per_outer, shape.elems())?;
                for (&p, &chunk) in parts.iter().zip(chunks) {
                    let np = self.arr(step, kinds, p, "concatenate")?;
                    if np != outer * chunk {
                        return Err(self.fail(
                            &step.name,
                            Invariant::Bounds,
                            format!(
                                "part `%{}` holds {np} elements, the copy pattern reads {}",
                                self.step_name(p),
                                outer * chunk
                            ),
                        ));
                    }
                }
                Ok(VKind::Arr(shape.elems()))
            }
            StepKind::Iota { size, suffix, shape } => {
                let out = shape.elems();
                if out > 0 && (*size == 0 || *suffix == 0 || out % (size * suffix) != 0) {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!("iota period {size}·{suffix} does not tile {out} elements"),
                    ));
                }
                Ok(VKind::Arr(out))
            }
            StepKind::Dot { lhs, rhs, plan, shape } => {
                let na = self.arr(step, kinds, *lhs, "dot lhs")?;
                let nb = self.arr(step, kinds, *rhs, "dot rhs")?;
                self.check_dot(step, plan, na, nb, shape.elems())?;
                Ok(VKind::Arr(shape.elems()))
            }
            StepKind::Reduce {
                a,
                init,
                kept_offsets,
                red_offsets,
                fast,
                to_apply,
                shape,
            } => {
                let na = self.arr(step, kinds, *a, "reduce")?;
                let ni = self.arr(step, kinds, *init, "reduce init")?;
                if ni != 1 {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!("reduce init holds {ni} elements, expected a scalar"),
                    ));
                }
                let out = shape.elems();
                if kept_offsets.len() != out {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Bounds,
                        format!(
                            "kept-offset table has {} entries for {out} outputs",
                            kept_offsets.len()
                        ),
                    ));
                }
                if out > 0 && !red_offsets.is_empty() {
                    let max = table_max(kept_offsets) + table_max(red_offsets);
                    if max >= na {
                        return Err(self.fail(
                            &step.name,
                            Invariant::Bounds,
                            format!(
                                "maximal reachable offset {max} is out of bounds for the \
                                 {na}-element operand"
                            ),
                        ));
                    }
                }
                if *to_apply >= self.plan.module.computations.len() {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Dataflow,
                        format!("to_apply region {to_apply} does not exist"),
                    ));
                }
                if fast.is_none() {
                    let region = &self.plan.module.computations[*to_apply];
                    if region.params.len() != 2 {
                        return Err(self.fail(
                            &step.name,
                            Invariant::Dataflow,
                            format!(
                                "reduce region `{}` takes {} parameters, needs 2",
                                region.name,
                                region.params.len()
                            ),
                        ));
                    }
                }
                Ok(VKind::Arr(out))
            }
            StepKind::MakeTuple(parts) => {
                Ok(VKind::Tup(parts.iter().map(|&p| kinds[p].clone()).collect()))
            }
            StepKind::Gte { a, index } => match &kinds[*a] {
                VKind::Tup(parts) => parts.get(*index).cloned().ok_or_else(|| {
                    self.fail(
                        &step.name,
                        Invariant::Dataflow,
                        format!(
                            "get-tuple-element {index} of `%{}`, a tuple of {} elements",
                            self.step_name(*a),
                            parts.len()
                        ),
                    )
                }),
                VKind::Arr(_) => Err(self.fail(
                    &step.name,
                    Invariant::Dataflow,
                    format!("get-tuple-element of `%{}`, which is not a tuple", self.step_name(*a)),
                )),
            },
        }
    }

    /// A parameter step must point at a declared slot and agree with the
    /// argument signature `execute` validates against.
    fn check_parameter(
        &self,
        idx: usize,
        step: &Step,
        p: usize,
        params_seen: &mut [bool],
    ) -> Result<VKind, PlanVerifyError> {
        if p >= self.comp.n_params {
            return Err(self.fail(
                &step.name,
                Invariant::Dataflow,
                format!("parameter index {p} out of range ({} declared)", self.comp.n_params),
            ));
        }
        params_seen[p] = true;
        let decl = self
            .plan
            .module
            .computations
            .iter()
            .find(|c| c.name == self.comp.name)
            .and_then(|c| c.instrs.get(idx))
            .map(|instr| &instr.shape);
        let Some(decl) = decl else {
            return Err(self.fail(
                &step.name,
                Invariant::Dataflow,
                "plan step does not correspond to a module instruction".to_string(),
            ));
        };
        let kind = decl_kind(decl);
        // the signature `execute` validates arguments against must agree
        // with what downstream steps assume this slot holds
        let sig = self.comp.param_shapes.get(p).and_then(|s| s.as_ref());
        match (&kind, sig) {
            (VKind::Arr(len), Some(s)) if s.elems() == *len => {}
            (VKind::Tup(_), None) => {}
            _ => {
                return Err(self.fail(
                    &step.name,
                    Invariant::Dataflow,
                    format!("parameter {p} signature disagrees with its declared shape"),
                ));
            }
        }
        Ok(kind)
    }

    fn expect_elems(&self, step: &Step, what: &str, got: usize, out: usize) -> VResult {
        if got != out {
            return Err(self.fail(
                &step.name,
                Invariant::Bounds,
                format!("{what} reads {got} elements into a {out}-element output"),
            ));
        }
        Ok(())
    }

    /// Gather: the odometer walk must stay inside the operand and its run
    /// accounting must produce exactly the output length.
    fn check_gather(
        &self,
        step: &Step,
        plan: &kernels::GatherPlan,
        operand_len: usize,
        out: usize,
    ) -> VResult {
        if plan.out_len != out {
            return Err(self.fail(
                &step.name,
                Invariant::Bounds,
                format!("gather produces {} elements, output holds {out}", plan.out_len),
            ));
        }
        if out == 0 {
            return Ok(()); // reads nothing at all
        }
        let runs: usize = plan.outer_sizes.iter().product();
        if plan.inner_len == 0 || runs * plan.inner_len != out {
            return Err(self.fail(
                &step.name,
                Invariant::Bounds,
                format!(
                    "run accounting {} runs × {} inner elements does not tile the \
                     {out}-element output",
                    runs, plan.inner_len
                ),
            ));
        }
        if plan.outer_sizes.len() != plan.outer_steps.len() {
            return Err(self.fail(
                &step.name,
                Invariant::Bounds,
                "gather odometer sizes/steps length mismatch".to_string(),
            ));
        }
        match plan.max_reachable_offset() {
            Some(max) if max >= operand_len => Err(self.fail(
                &step.name,
                Invariant::Bounds,
                format!(
                    "maximal reachable offset {max} is out of bounds for the \
                     {operand_len}-element operand"
                ),
            )),
            _ => Ok(()),
        }
    }

    /// Dot-general: offset tables in bounds, and the row partition tiles
    /// the output exactly at every thread count execution could use.
    fn check_dot(
        &self,
        step: &Step,
        plan: &kernels::DotPlan,
        lhs_len: usize,
        rhs_len: usize,
        out: usize,
    ) -> VResult {
        if plan.out_len != out {
            return Err(self.fail(
                &step.name,
                Invariant::Bounds,
                format!("dot produces {} elements, output holds {out}", plan.out_len),
            ));
        }
        if plan.bl.len() != plan.br.len() || plan.cl.len() != plan.cr.len() {
            return Err(self.fail(
                &step.name,
                Invariant::Bounds,
                format!(
                    "lockstep tables diverge: batch {}/{}, contraction {}/{}",
                    plan.bl.len(),
                    plan.br.len(),
                    plan.cl.len(),
                    plan.cr.len()
                ),
            ));
        }
        if plan.rf_contiguous && !plan.rf.iter().enumerate().all(|(i, &o)| o == i) {
            return Err(self.fail(
                &step.name,
                Invariant::Bounds,
                "rf_contiguous is set but the rhs free offsets are not 0,1,2,…".to_string(),
            ));
        }
        let nrf = plan.rf.len();
        let rows = plan.bl.len() * plan.lf.len();
        if rows.saturating_mul(nrf) != out {
            return Err(self.fail(
                &step.name,
                Invariant::Partition,
                format!(
                    "{rows} partitioned rows × {nrf} columns would not cover the \
                     {out}-element output exactly — thread chunks would overlap or overrun"
                ),
            ));
        }
        if rows == 0 || nrf == 0 {
            return Ok(()); // execution returns before touching anything
        }
        if !plan.cl.is_empty() {
            let lmax = table_max(&plan.bl) + table_max(&plan.lf) + table_max(&plan.cl);
            if lmax >= lhs_len {
                return Err(self.fail(
                    &step.name,
                    Invariant::Bounds,
                    format!(
                        "maximal reachable lhs offset {lmax} is out of bounds for the \
                         {lhs_len}-element operand"
                    ),
                ));
            }
            let rmax = table_max(&plan.br) + table_max(&plan.cr) + table_max(&plan.rf);
            if rmax >= rhs_len {
                return Err(self.fail(
                    &step.name,
                    Invariant::Bounds,
                    format!(
                        "maximal reachable rhs offset {rmax} is out of bounds for the \
                         {rhs_len}-element operand"
                    ),
                ));
            }
        }
        // re-check the partition at every thread count execution could
        // engage (plus a spread of fixed counts, so the check does not
        // depend on the machine it runs on)
        let mut counts = vec![1usize, 2, 3, 4, 5, 8];
        counts.push(kernels::resolve_dot_threads());
        for requested in counts {
            let threads = plan.effective_threads(requested, rows);
            let parts = kernels::partition_rows(rows, threads);
            let mut next = 0usize;
            for &(start, end) in &parts {
                if start != next || end <= start || end > rows {
                    return Err(self.fail(
                        &step.name,
                        Invariant::Partition,
                        format!(
                            "thread partition at {threads} threads emits chunk \
                             {start}..{end} after row {next} — rows would be skipped \
                             or written twice"
                        ),
                    ));
                }
                next = end;
            }
            if next != rows {
                return Err(self.fail(
                    &step.name,
                    Invariant::Partition,
                    format!("thread partition at {threads} threads covers {next} of {rows} rows"),
                ));
            }
        }
        Ok(())
    }
}

/// Test-only corruption hooks for the mutation harness
/// (`tests/verifier.rs`). Not part of the public API.
#[doc(hidden)]
pub mod mutate {
    use std::sync::Arc;

    use crate::plan::{ExecPlan, StepKind};

    /// A class of plan corruption the verifier must reject.
    #[derive(Debug, Clone, Copy, PartialEq, Eq)]
    pub enum Corruption {
        /// Bump a gather's innermost stride by one, walking it past the
        /// end of its operand.
        GatherStrideOffByOne,
        /// Move a slot's free point up to its defining step, before
        /// readers that still need it.
        PrematureFree,
        /// Free an already-freed slot a second time.
        DoubleFree,
        /// Duplicate a dot row so the thread partition would overrun the
        /// output.
        OverlappingThreadRows,
        /// Point an alias at a slot that does not exist.
        DanglingAlias,
    }

    /// Apply `c` to the first eligible instruction of the entry
    /// computation. Returns the corrupted instruction's name (the one a
    /// verify error must report), or `None` if the plan has no eligible
    /// site.
    pub fn corrupt(plan: &mut ExecPlan, c: Corruption) -> Option<String> {
        let module = Arc::clone(&plan.module);
        let entry = module.entry;
        let comp = &mut plan.comps[entry];
        match c {
            Corruption::GatherStrideOffByOne => {
                for step in &mut comp.steps {
                    if let StepKind::Gather { plan: g, .. } = &mut step.kind {
                        if g.out_len == 0 {
                            continue;
                        }
                        g.inner_step += 1;
                        return Some(step.name.clone());
                    }
                }
                None
            }
            Corruption::PrematureFree => {
                let n = comp.steps.len();
                let mut last_use: Vec<usize> = (0..n).collect();
                for (idx, step) in comp.steps.iter().enumerate() {
                    for o in step.kind.operands() {
                        last_use[o] = last_use[o].max(idx);
                    }
                }
                for slot in 0..n {
                    let at = last_use[slot];
                    if slot == comp.root || at <= slot {
                        continue;
                    }
                    let pos = comp.free_after[at].iter().position(|&d| d == slot);
                    if let Some(pos) = pos {
                        comp.free_after[at].remove(pos);
                        comp.free_after[slot].push(slot);
                        return Some(comp.steps[slot].name.clone());
                    }
                }
                None
            }
            Corruption::DoubleFree => {
                let root = comp.root;
                for at in 0..comp.free_after.len() {
                    let first = comp.free_after[at].first().copied();
                    if let Some(d) = first {
                        comp.free_after[root].push(d);
                        return Some(comp.steps[d].name.clone());
                    }
                }
                None
            }
            Corruption::OverlappingThreadRows => {
                for step in &mut comp.steps {
                    if let StepKind::Dot { plan: d, .. } = &mut step.kind {
                        if d.lf.is_empty() {
                            continue;
                        }
                        let dup = d.lf[0];
                        d.lf.push(dup);
                        return Some(step.name.clone());
                    }
                }
                None
            }
            Corruption::DanglingAlias => {
                for step in &mut comp.steps {
                    if let StepKind::Alias { a, .. } = &mut step.kind {
                        *a = usize::MAX;
                        return Some(step.name.clone());
                    }
                }
                None
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use std::sync::Arc;

    use crate::plan::ExecPlan;

    const SMOKE: &str = "HloModule vsmoke\n\nENTRY %main (x: f32[2,3]) -> f32[3,2] {\n  \
                         %x = f32[2,3]{1,0} parameter(0)\n  \
                         ROOT %t = f32[3,2]{1,0} transpose(f32[2,3] %x), dimensions={1,0}\n}\n";

    #[test]
    fn clean_plan_verifies() {
        let module = Arc::new(crate::parser::parse_module(SMOKE).unwrap());
        let plan = ExecPlan::new(module).unwrap();
        plan.verify().unwrap();
    }

    #[test]
    fn debug_builds_always_verify() {
        // tests compile with debug_assertions, so compile-time
        // verification must be on regardless of the knob
        assert!(super::verify_plans());
    }

    #[test]
    fn error_display_names_instruction_and_invariant() {
        let err = super::PlanVerifyError {
            computation: "main".to_string(),
            instruction: "dot.1".to_string(),
            invariant: super::Invariant::Partition,
            detail: "boom".to_string(),
        };
        let msg = err.to_string();
        assert!(msg.contains("%dot.1") && msg.contains("[partition]") && msg.contains("boom"));
    }
}
