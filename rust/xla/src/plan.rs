//! Compile-time lowering of parsed HLO modules into execution plans.
//!
//! `PjRtClient::compile` calls [`ExecPlan::new`] once per executable. The
//! plan precomputes everything the reference evaluator re-derives on every
//! `execute_b`:
//!
//! * each instruction's resolved output [`Shape`] and all shape/stride
//!   validation (a malformed module now fails at compile time, naming the
//!   instruction, instead of on first execution);
//! * offset tables and odometer walkers for broadcast / transpose /
//!   slice / iota / reduce / dot-general ([`GatherPlan`] / [`DotPlan`]);
//! * [`fast_reducer`] recognition for `reduce` regions;
//! * per-slot **last-use liveness**: after the last step that reads a
//!   slot, its buffer is handed back to the [`Arena`] and recycled by
//!   later allocations, instead of every intermediate living to the end;
//! * the entry parameter signature, so `execute` validates argument dims
//!   up front and `Op::Parameter` becomes a refcount bump.
//!
//! Execution then walks the step list with no per-call `div`/`mod`
//! coordinate math and no per-op re-validation. The numerics contract is
//! bit-exactness against [`crate::interp::evaluate`] — asserted by
//! `tests/differential.rs` — including the dot-general accumulation order
//! at every `threads` setting.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::Arc;

use crate::interp::{self, ArrayValue, Value};
use crate::kernels::{self, Arena, DotPlan, GatherPlan};
use crate::parser::{BinaryOp, CmpDir, Computation, Module, Op, Shape, UnaryOp};
use crate::{Error, Result};

/// A compiled module: one [`CompPlan`] per computation.
///
/// Fields are crate-visible so the static analyzer in [`crate::verify`]
/// (and its mutation hooks) can inspect — and, under test, corrupt —
/// compiled plans without an execution-side API.
#[derive(Debug)]
pub struct ExecPlan {
    pub(crate) module: Arc<Module>,
    pub(crate) comps: Vec<CompPlan>,
}

#[derive(Debug)]
pub(crate) struct CompPlan {
    pub(crate) name: String,
    pub(crate) steps: Vec<Step>,
    /// Slots whose last use is step `i` (never includes the root).
    pub(crate) free_after: Vec<Vec<usize>>,
    pub(crate) root: usize,
    pub(crate) n_params: usize,
    /// Declared array shape per parameter (`None` for tuple-shaped).
    pub(crate) param_shapes: Vec<Option<Shape>>,
}

#[derive(Debug)]
pub(crate) struct Step {
    pub(crate) name: String,
    pub(crate) kind: StepKind,
}

/// How a binary/compare step pairs its operands (resolved at plan time
/// from the declared shapes; mirrors `interp::zip_broadcast`).
#[derive(Debug, Clone, Copy)]
pub(crate) enum EwForm {
    Equal,
    AScalar,
    BScalar,
}

#[derive(Debug)]
pub(crate) enum StepKind {
    Parameter(usize),
    /// Constant materialised once at plan time; execution is an Arc bump.
    Constant(Value),
    Unary {
        op: UnaryOp,
        a: usize,
        shape: Shape,
    },
    Binary {
        op: BinaryOp,
        a: usize,
        b: usize,
        form: EwForm,
        shape: Shape,
    },
    Compare {
        dir: CmpDir,
        a: usize,
        b: usize,
        form: EwForm,
        shape: Shape,
    },
    Select {
        pred: usize,
        on_true: usize,
        on_false: usize,
        pred_scalar: bool,
        shape: Shape,
    },
    /// Broadcast of a single-element operand.
    Fill {
        a: usize,
        shape: Shape,
    },
    /// Broadcast / transpose / slice as one precomputed strided copy.
    Gather {
        a: usize,
        plan: GatherPlan,
        shape: Shape,
    },
    /// Reshape / copy / width-only convert: same storage, new shape.
    Alias {
        a: usize,
        shape: Shape,
    },
    ConvertInt {
        a: usize,
        shape: Shape,
    },
    ConvertPred {
        a: usize,
        shape: Shape,
    },
    Concat {
        parts: Vec<usize>,
        /// `dims[dim] * inner` per part.
        chunks: Vec<usize>,
        outer: usize,
        shape: Shape,
    },
    Iota {
        size: usize,
        suffix: usize,
        shape: Shape,
    },
    Dot {
        lhs: usize,
        rhs: usize,
        plan: DotPlan,
        shape: Shape,
    },
    Reduce {
        a: usize,
        init: usize,
        kept_offsets: Vec<usize>,
        red_offsets: Vec<usize>,
        fast: Option<BinaryOp>,
        to_apply: usize,
        shape: Shape,
    },
    MakeTuple(Vec<usize>),
    Gte {
        a: usize,
        index: usize,
    },
}

impl ExecPlan {
    /// Lower every computation of `module` into planned steps.
    pub fn new(module: Arc<Module>) -> Result<ExecPlan> {
        let comps = module
            .computations
            .iter()
            .map(|comp| plan_computation(&module, comp))
            .collect::<Result<Vec<_>>>()?;
        Ok(ExecPlan { module, comps })
    }

    /// Run the entry computation against `args`, recycling intermediates
    /// through `arena`.
    pub fn execute_entry(&self, args: &[Value], arena: &mut Arena) -> Result<Value> {
        self.execute(self.module.entry, args, arena)
    }

    fn execute(&self, comp_idx: usize, args: &[Value], arena: &mut Arena) -> Result<Value> {
        let comp = &self.comps[comp_idx];
        if args.len() != comp.n_params {
            return Err(Error::msg(format!(
                "computation `{}` takes {} parameters, got {} arguments",
                comp.name,
                comp.n_params,
                args.len()
            )));
        }
        for (n, decl) in comp.param_shapes.iter().enumerate() {
            if let (Some(decl), Value::Array(a)) = (decl, &args[n]) {
                if decl.elems() != a.data.len() {
                    return Err(Error::msg(format!(
                        "parameter {n} expects shape {:?} ({} elements), argument has {}",
                        decl.dims,
                        decl.elems(),
                        a.data.len()
                    )));
                }
                if decl.dims != a.shape.dims {
                    return Err(Error::msg(format!(
                        "parameter {n} expects dims {:?}, argument uploaded as {:?}",
                        decl.dims, a.shape.dims
                    )));
                }
            }
        }
        // Loaded once per execution: sampling off costs one relaxed load
        // per `execute`, not per step.
        let trace = crate::op_trace_config();
        let mut slots: Vec<Option<Value>> = vec![None; comp.steps.len()];
        for (idx, step) in comp.steps.iter().enumerate() {
            let timed = match trace {
                Some((sample, _)) => OP_COUNTER.fetch_add(1, Ordering::Relaxed) % sample == 0,
                None => false,
            };
            let start = timed.then(std::time::Instant::now);
            let value = self
                .run_step(step, &slots, args, arena)
                .map_err(|e| {
                    Error::msg(format!(
                        "evaluating `%{}` in computation `{}`: {e}",
                        step.name, comp.name
                    ))
                })?;
            if let (Some(start), Some((_, sink))) = (start, trace) {
                let dur = u64::try_from(start.elapsed().as_micros()).unwrap_or(u64::MAX);
                sink(step.kind.label(), &comp.name, dur);
            }
            slots[idx] = Some(value);
            for &dead in &comp.free_after[idx] {
                if let Some(v) = slots[dead].take() {
                    recycle_value(arena, v);
                }
            }
        }
        slots[comp.root]
            .take()
            .ok_or_else(|| Error::msg("root instruction produced no value"))
    }

    fn run_step(
        &self,
        step: &Step,
        slots: &[Option<Value>],
        args: &[Value],
        arena: &mut Arena,
    ) -> Result<Value> {
        match &step.kind {
            StepKind::Parameter(n) => args
                .get(*n)
                .cloned()
                .ok_or_else(|| Error::msg(format!("missing argument {n}"))),
            StepKind::Constant(value) => Ok(value.clone()),
            StepKind::Unary { op, a, shape } => {
                let a = get_array(slots, *a)?;
                let mut out = arena.alloc(shape.elems());
                for (o, &v) in out.iter_mut().zip(a.data.iter()) {
                    *o = interp::unary(*op, v);
                }
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Binary {
                op,
                a,
                b,
                form,
                shape,
            } => {
                let (a, b) = (get_array(slots, *a)?, get_array(slots, *b)?);
                let mut out = arena.alloc(shape.elems());
                ew_binary(|x, y| interp::binary_scalar(*op, x, y), a, b, *form, &mut out);
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Compare {
                dir,
                a,
                b,
                form,
                shape,
            } => {
                let (a, b) = (get_array(slots, *a)?, get_array(slots, *b)?);
                let mut out = arena.alloc(shape.elems());
                ew_binary(|x, y| interp::compare_scalar(*dir, x, y), a, b, *form, &mut out);
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Select {
                pred,
                on_true,
                on_false,
                pred_scalar,
                shape,
            } => {
                let p = get_array(slots, *pred)?;
                let t = get_array(slots, *on_true)?;
                let f = get_array(slots, *on_false)?;
                if *pred_scalar {
                    let picked = if p.data[0] != 0.0 { t } else { f };
                    return ArrayValue::from_arc(shape.clone(), Arc::clone(&picked.data))
                        .map(Value::Array);
                }
                let mut out = arena.alloc(shape.elems());
                for ((o, &p), (&t, &f)) in out
                    .iter_mut()
                    .zip(p.data.iter())
                    .zip(t.data.iter().zip(f.data.iter()))
                {
                    *o = if p != 0.0 { t } else { f };
                }
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Fill { a, shape } => {
                let a = get_array(slots, *a)?;
                let mut out = arena.alloc(shape.elems());
                out.fill(a.data[0]);
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Gather { a, plan, shape } => {
                let a = get_array(slots, *a)?;
                let mut out = arena.alloc(plan.out_len());
                plan.run(&a.data, &mut out);
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Alias { a, shape } => {
                let a = get_array(slots, *a)?;
                ArrayValue::from_arc(shape.clone(), Arc::clone(&a.data)).map(Value::Array)
            }
            StepKind::ConvertInt { a, shape } => {
                let a = get_array(slots, *a)?;
                let mut out = arena.alloc(shape.elems());
                for (o, &v) in out.iter_mut().zip(a.data.iter()) {
                    *o = v.trunc();
                }
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::ConvertPred { a, shape } => {
                let a = get_array(slots, *a)?;
                let mut out = arena.alloc(shape.elems());
                for (o, &v) in out.iter_mut().zip(a.data.iter()) {
                    *o = if v != 0.0 { 1.0 } else { 0.0 };
                }
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Concat {
                parts,
                chunks,
                outer,
                shape,
            } => {
                let values = parts
                    .iter()
                    .map(|&i| get_array(slots, i))
                    .collect::<Result<Vec<_>>>()?;
                let mut out = arena.alloc(shape.elems());
                let mut o = 0usize;
                for oidx in 0..*outer {
                    for (p, &chunk) in values.iter().zip(chunks) {
                        out[o..o + chunk]
                            .copy_from_slice(&p.data[oidx * chunk..(oidx + 1) * chunk]);
                        o += chunk;
                    }
                }
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Iota { size, suffix, shape } => {
                let mut out = arena.alloc(shape.elems());
                kernels::iota_fill(&mut out, *size, *suffix);
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Dot {
                lhs,
                rhs,
                plan,
                shape,
            } => {
                let (a, b) = (get_array(slots, *lhs)?, get_array(slots, *rhs)?);
                let mut out = arena.alloc(plan.out_len);
                plan.execute(&a.data, &b.data, &mut out, kernels::resolve_dot_threads());
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::Reduce {
                a,
                init,
                kept_offsets,
                red_offsets,
                fast,
                to_apply,
                shape,
            } => {
                let arr = get_array(slots, *a)?;
                let init = get_array(slots, *init)?;
                if init.data.len() != 1 {
                    return Err(Error::msg("reduce init value must be a scalar"));
                }
                let init = init.data[0];
                let mut out = arena.alloc(shape.elems());
                out.fill(init);
                match fast {
                    Some(op) => {
                        for (o, &ko) in out.iter_mut().zip(kept_offsets) {
                            let mut acc = *o;
                            for &ro in red_offsets {
                                acc = interp::binary_scalar(*op, acc, arr.data[ko + ro]);
                            }
                            *o = acc;
                        }
                    }
                    None => {
                        // rare: interpret the region per element, exactly
                        // like the reference evaluator
                        let dtype = arr.shape.dtype;
                        for (o, &ko) in out.iter_mut().zip(kept_offsets) {
                            let mut acc = *o;
                            for &ro in red_offsets {
                                let r = interp::evaluate(
                                    &self.module,
                                    *to_apply,
                                    &[
                                        Value::Array(ArrayValue::scalar(acc, dtype)),
                                        Value::Array(ArrayValue::scalar(arr.data[ko + ro], dtype)),
                                    ],
                                )?;
                                acc = r.array()?.data[0];
                            }
                            *o = acc;
                        }
                    }
                }
                ArrayValue::new(shape.clone(), out).map(Value::Array)
            }
            StepKind::MakeTuple(parts) => {
                let elems = parts
                    .iter()
                    .map(|&i| get(slots, i).cloned())
                    .collect::<Result<Vec<_>>>()?;
                Ok(Value::Tuple(elems))
            }
            StepKind::Gte { a, index } => match get(slots, *a)? {
                Value::Tuple(elems) => elems
                    .get(*index)
                    .cloned()
                    .ok_or_else(|| Error::msg(format!("tuple has no element {index}"))),
                Value::Array(_) => Err(Error::msg("get-tuple-element of a non-tuple")),
            },
        }
    }
}

/// Process-wide executed-step counter driving `every Nth step` sampling
/// (see [`crate::set_op_trace`]): a per-execution counter would always
/// sample the same leading steps of every short module.
static OP_COUNTER: AtomicU64 = AtomicU64::new(0);

fn get<'a>(slots: &'a [Option<Value>], idx: usize) -> Result<&'a Value> {
    slots
        .get(idx)
        .and_then(|v| v.as_ref())
        .ok_or_else(|| Error::msg("operand evaluated out of order (or freed early)"))
}

fn get_array<'a>(slots: &'a [Option<Value>], idx: usize) -> Result<&'a ArrayValue> {
    get(slots, idx)?.array()
}

fn ew_binary(
    f: impl Fn(f32, f32) -> f32,
    a: &ArrayValue,
    b: &ArrayValue,
    form: EwForm,
    out: &mut [f32],
) {
    match form {
        EwForm::Equal => {
            for ((o, &x), &y) in out.iter_mut().zip(a.data.iter()).zip(b.data.iter()) {
                *o = f(x, y);
            }
        }
        EwForm::AScalar => {
            let x = a.data[0];
            for (o, &y) in out.iter_mut().zip(b.data.iter()) {
                *o = f(x, y);
            }
        }
        EwForm::BScalar => {
            let y = b.data[0];
            for (o, &x) in out.iter_mut().zip(a.data.iter()) {
                *o = f(x, y);
            }
        }
    }
}

/// Drop a dead slot value, recycling any uniquely-owned array storage.
fn recycle_value(arena: &mut Arena, value: Value) {
    match value {
        Value::Array(a) => arena.recycle(a.data),
        Value::Tuple(elems) => {
            for e in elems {
                recycle_value(arena, e);
            }
        }
    }
}

impl StepKind {
    /// Stable label for sampled per-op trace spans.
    pub(crate) fn label(&self) -> &'static str {
        match self {
            StepKind::Parameter(_) => "parameter",
            StepKind::Constant(_) => "constant",
            StepKind::Unary { .. } => "unary",
            StepKind::Binary { .. } => "binary",
            StepKind::Compare { .. } => "compare",
            StepKind::Select { .. } => "select",
            StepKind::Fill { .. } => "fill",
            StepKind::Gather { .. } => "gather",
            StepKind::Alias { .. } => "alias",
            StepKind::ConvertInt { .. } => "convert_int",
            StepKind::ConvertPred { .. } => "convert_pred",
            StepKind::Concat { .. } => "concat",
            StepKind::Iota { .. } => "iota",
            StepKind::Dot { .. } => "dot",
            StepKind::Reduce { .. } => "reduce",
            StepKind::MakeTuple(_) => "tuple",
            StepKind::Gte { .. } => "gte",
        }
    }

    /// Slot indices this planned step reads at execution time, in
    /// evaluation order. This is the step-level mirror of [`op_operands`]
    /// and is what the verifier's liveness/dataflow checks are defined
    /// over — a plan mutation that redirects an operand is judged by what
    /// execution would actually read, not by the source module.
    pub(crate) fn operands(&self) -> Vec<usize> {
        match self {
            StepKind::Parameter(_) | StepKind::Constant(_) | StepKind::Iota { .. } => vec![],
            StepKind::Unary { a, .. }
            | StepKind::Fill { a, .. }
            | StepKind::Gather { a, .. }
            | StepKind::Alias { a, .. }
            | StepKind::ConvertInt { a, .. }
            | StepKind::ConvertPred { a, .. }
            | StepKind::Gte { a, .. } => vec![*a],
            StepKind::Binary { a, b, .. } | StepKind::Compare { a, b, .. } => vec![*a, *b],
            StepKind::Select {
                pred,
                on_true,
                on_false,
                ..
            } => vec![*pred, *on_true, *on_false],
            StepKind::Concat { parts, .. } => parts.clone(),
            StepKind::Dot { lhs, rhs, .. } => vec![*lhs, *rhs],
            StepKind::Reduce { a, init, .. } => vec![*a, *init],
            StepKind::MakeTuple(parts) => parts.clone(),
        }
    }
}

/// Slot indices an op reads, in evaluation order.
fn op_operands(op: &Op) -> Vec<usize> {
    match op {
        Op::Parameter(_) | Op::Constant(_) | Op::Iota { .. } => vec![],
        Op::Unary(_, a) | Op::Reshape(a) | Op::Copy(a) | Op::Convert(a) => vec![*a],
        Op::Binary(_, a, b) => vec![*a, *b],
        Op::Compare { lhs, rhs, .. } => vec![*lhs, *rhs],
        Op::Select {
            pred,
            on_true,
            on_false,
        } => vec![*pred, *on_true, *on_false],
        Op::Broadcast { operand, .. }
        | Op::Transpose { operand, .. }
        | Op::Slice { operand, .. }
        | Op::GetTupleElement { operand, .. } => vec![*operand],
        Op::Concat { operands, .. } => operands.clone(),
        Op::Tuple(operands) => operands.clone(),
        Op::Dot { lhs, rhs, .. } => vec![*lhs, *rhs],
        Op::Reduce { operand, init, .. } => vec![*operand, *init],
    }
}

fn plan_computation(module: &Module, comp: &Computation) -> Result<CompPlan> {
    let mut steps = Vec::with_capacity(comp.instrs.len());
    for (idx, instr) in comp.instrs.iter().enumerate() {
        let kind = plan_instr(module, comp, idx).map_err(|e| {
            Error::msg(format!(
                "planning `%{}` in computation `{}`: {e}",
                instr.name, comp.name
            ))
        })?;
        steps.push(Step {
            name: instr.name.clone(),
            kind,
        });
    }
    // last-use liveness: slot s may be freed right after the last step
    // that reads it (a never-read slot dies at its own step)
    let n = comp.instrs.len();
    let mut last_use: Vec<usize> = (0..n).collect();
    for (idx, instr) in comp.instrs.iter().enumerate() {
        for operand in op_operands(&instr.op) {
            last_use[operand] = idx;
        }
    }
    let mut free_after: Vec<Vec<usize>> = vec![Vec::new(); n];
    for (slot, &at) in last_use.iter().enumerate() {
        if slot != comp.root {
            free_after[at].push(slot);
        }
    }
    let param_shapes = comp
        .params
        .iter()
        .map(|&i| comp.instrs[i].shape.array().ok().cloned())
        .collect();
    Ok(CompPlan {
        name: comp.name.clone(),
        steps,
        free_after,
        root: comp.root,
        n_params: comp.params.len(),
        param_shapes,
    })
}

fn arr_shape<'a>(comp: &'a Computation, idx: usize) -> Result<&'a Shape> {
    comp.instrs[idx].shape.array()
}

/// Binary/compare operand pairing from declared element counts (mirrors
/// the implicit-scalar-broadcast liberty of `interp::zip_broadcast`).
fn ew_form(a: &Shape, b: &Shape) -> Result<EwForm> {
    let (na, nb) = (a.elems(), b.elems());
    if na == nb {
        Ok(EwForm::Equal)
    } else if na == 1 {
        Ok(EwForm::AScalar)
    } else if nb == 1 {
        Ok(EwForm::BScalar)
    } else {
        Err(Error::msg(format!(
            "elementwise operands have mismatched sizes {na} vs {nb}"
        )))
    }
}

fn check_elems(what: &str, got: usize, shape: &Shape) -> Result<()> {
    if shape.elems() != got {
        return Err(Error::msg(format!(
            "{what}: declared shape {:?} holds {} elements, computation produces {got}",
            shape.dims,
            shape.elems()
        )));
    }
    Ok(())
}

fn plan_instr(module: &Module, comp: &Computation, idx: usize) -> Result<StepKind> {
    let instr = &comp.instrs[idx];
    match &instr.op {
        Op::Parameter(n) => Ok(StepKind::Parameter(*n)),
        Op::Constant(data) => {
            let shape = instr.shape.array()?.clone();
            let value = ArrayValue::new(shape, data.clone())?;
            Ok(StepKind::Constant(Value::Array(value)))
        }
        Op::Unary(op, a) => {
            let shape = instr.shape.array()?.clone();
            check_elems("unary", arr_shape(comp, *a)?.elems(), &shape)?;
            Ok(StepKind::Unary {
                op: *op,
                a: *a,
                shape,
            })
        }
        Op::Binary(op, a, b) => {
            let shape = instr.shape.array()?.clone();
            let form = ew_form(arr_shape(comp, *a)?, arr_shape(comp, *b)?)?;
            check_elems(
                "binary",
                arr_shape(comp, *a)?.elems().max(arr_shape(comp, *b)?.elems()),
                &shape,
            )?;
            Ok(StepKind::Binary {
                op: *op,
                a: *a,
                b: *b,
                form,
                shape,
            })
        }
        Op::Compare { dir, lhs, rhs } => {
            let shape = instr.shape.array()?.clone();
            let form = ew_form(arr_shape(comp, *lhs)?, arr_shape(comp, *rhs)?)?;
            check_elems(
                "compare",
                arr_shape(comp, *lhs)?
                    .elems()
                    .max(arr_shape(comp, *rhs)?.elems()),
                &shape,
            )?;
            Ok(StepKind::Compare {
                dir: *dir,
                a: *lhs,
                b: *rhs,
                form,
                shape,
            })
        }
        Op::Select {
            pred,
            on_true,
            on_false,
        } => {
            let shape = instr.shape.array()?.clone();
            let (pt, pf) = (arr_shape(comp, *on_true)?, arr_shape(comp, *on_false)?);
            if pt.elems() != pf.elems() {
                return Err(Error::msg("select branches have mismatched sizes"));
            }
            let p = arr_shape(comp, *pred)?;
            let pred_scalar = p.elems() == 1;
            if !pred_scalar && p.elems() != pt.elems() {
                return Err(Error::msg("select predicate has mismatched size"));
            }
            check_elems("select", pt.elems(), &shape)?;
            Ok(StepKind::Select {
                pred: *pred,
                on_true: *on_true,
                on_false: *on_false,
                pred_scalar,
                shape,
            })
        }
        Op::Broadcast { operand, dims } => {
            let shape = instr.shape.array()?.clone();
            let a = arr_shape(comp, *operand)?;
            if dims.len() != a.dims.len() {
                return Err(Error::msg(format!(
                    "broadcast dimensions {:?} do not match operand rank {}",
                    dims,
                    a.dims.len()
                )));
            }
            interp::check_broadcast_dims_increasing(dims)?;
            for (i, &d) in dims.iter().enumerate() {
                if d >= shape.dims.len() || shape.dims[d] != a.dims[i] {
                    return Err(Error::msg(format!(
                        "broadcast maps operand dim {i} (size {}) to output dim {d} of {:?}",
                        a.dims[i], shape.dims
                    )));
                }
            }
            if a.elems() == 1 {
                return Ok(StepKind::Fill {
                    a: *operand,
                    shape,
                });
            }
            let a_strides = a.strides();
            let mut steps = vec![0usize; shape.dims.len()];
            for (i, &d) in dims.iter().enumerate() {
                steps[d] = a_strides[i];
            }
            let plan = GatherPlan::new(&shape.dims, &steps, 0);
            Ok(StepKind::Gather {
                a: *operand,
                plan,
                shape,
            })
        }
        Op::Reshape(operand) | Op::Copy(operand) => {
            let shape = instr.shape.array()?.clone();
            check_elems("reshape/copy", arr_shape(comp, *operand)?.elems(), &shape)?;
            Ok(StepKind::Alias {
                a: *operand,
                shape,
            })
        }
        Op::Convert(operand) => {
            let shape = instr.shape.array()?.clone();
            check_elems("convert", arr_shape(comp, *operand)?.elems(), &shape)?;
            if shape.dtype.is_integer() {
                Ok(StepKind::ConvertInt {
                    a: *operand,
                    shape,
                })
            } else if shape.dtype == crate::parser::DType::Pred {
                Ok(StepKind::ConvertPred {
                    a: *operand,
                    shape,
                })
            } else {
                Ok(StepKind::Alias {
                    a: *operand,
                    shape,
                })
            }
        }
        Op::Transpose { operand, perm } => {
            let shape = instr.shape.array()?.clone();
            let a = arr_shape(comp, *operand)?;
            if perm.len() != a.dims.len() {
                return Err(Error::msg("transpose permutation rank mismatch"));
            }
            let mut seen = vec![false; perm.len()];
            for (i, &p) in perm.iter().enumerate() {
                if p >= a.dims.len() || std::mem::replace(&mut seen[p], true) {
                    return Err(Error::msg(format!(
                        "transpose dimensions {perm:?} are not a permutation"
                    )));
                }
                if shape.dims.get(i) != Some(&a.dims[p]) {
                    return Err(Error::msg(format!(
                        "transpose output dim {i} should be {} (operand dim {p}), declared {:?}",
                        a.dims[p], shape.dims
                    )));
                }
            }
            let a_strides = a.strides();
            let steps: Vec<usize> = perm.iter().map(|&p| a_strides[p]).collect();
            let plan = GatherPlan::new(&shape.dims, &steps, 0);
            Ok(StepKind::Gather {
                a: *operand,
                plan,
                shape,
            })
        }
        Op::Slice {
            operand,
            starts,
            limits,
            strides,
        } => {
            let shape = instr.shape.array()?.clone();
            let a = arr_shape(comp, *operand)?;
            let rank = a.dims.len();
            if starts.len() != rank || limits.len() != rank || strides.len() != rank {
                return Err(Error::msg("slice spec rank mismatch"));
            }
            for d in 0..rank {
                if limits[d] > a.dims[d] || starts[d] > limits[d] || strides[d] == 0 {
                    return Err(Error::msg(format!(
                        "slice [{}:{}:{}] out of bounds for dim {d} (size {})",
                        starts[d], limits[d], strides[d], a.dims[d]
                    )));
                }
                let produced = (limits[d] - starts[d]).div_ceil(strides[d]);
                if shape.dims.get(d) != Some(&produced) {
                    return Err(Error::msg(format!(
                        "slice [{}:{}:{}] produces {produced} elements along dim {d}, \
                         declared shape says {:?}",
                        starts[d], limits[d], strides[d], shape.dims
                    )));
                }
            }
            let a_strides = a.strides();
            let base: usize = starts.iter().zip(&a_strides).map(|(&s, &st)| s * st).sum();
            let steps: Vec<usize> = strides
                .iter()
                .zip(&a_strides)
                .map(|(&s, &st)| s * st)
                .collect();
            let plan = GatherPlan::new(&shape.dims, &steps, base);
            Ok(StepKind::Gather {
                a: *operand,
                plan,
                shape,
            })
        }
        Op::Concat { operands, dim } => {
            let shape = instr.shape.array()?.clone();
            if operands.is_empty() {
                return Err(Error::msg("concatenate of zero operands"));
            }
            let first = arr_shape(comp, operands[0])?;
            let rank = first.dims.len();
            if *dim >= rank {
                return Err(Error::msg("concatenate dimension out of range"));
            }
            for (i, &oi) in operands.iter().enumerate() {
                let p = arr_shape(comp, oi)?;
                if p.dims.len() != rank
                    || p.dims
                        .iter()
                        .zip(&first.dims)
                        .enumerate()
                        .any(|(d, (a, b))| d != *dim && a != b)
                {
                    return Err(Error::msg(format!(
                        "concatenate operand {i} has shape {:?}, incompatible with {:?} along dim {dim}",
                        p.dims, first.dims
                    )));
                }
            }
            let outer: usize = first.dims[..*dim].iter().product();
            let inner: usize = first.dims[*dim + 1..].iter().product();
            let mut chunks = Vec::with_capacity(operands.len());
            let mut total = 0usize;
            for &oi in operands {
                let chunk = arr_shape(comp, oi)?.dims[*dim] * inner;
                total += chunk;
                chunks.push(chunk);
            }
            check_elems("concatenate", outer * total, &shape)?;
            Ok(StepKind::Concat {
                parts: operands.clone(),
                chunks,
                outer,
                shape,
            })
        }
        Op::Iota { dim } => {
            let shape = instr.shape.array()?.clone();
            if *dim >= shape.dims.len() {
                return Err(Error::msg(format!(
                    "iota_dimension {dim} out of range for shape {:?}",
                    shape.dims
                )));
            }
            let strides = shape.strides();
            Ok(StepKind::Iota {
                size: shape.dims[*dim],
                suffix: strides[*dim],
                shape,
            })
        }
        Op::Dot {
            lhs,
            rhs,
            lhs_contracting,
            rhs_contracting,
            lhs_batch,
            rhs_batch,
        } => {
            let shape = instr.shape.array()?.clone();
            let a = arr_shape(comp, *lhs)?;
            let b = arr_shape(comp, *rhs)?;
            let plan = build_dot_plan(
                a,
                b,
                lhs_contracting,
                rhs_contracting,
                lhs_batch,
                rhs_batch,
                &shape,
            )?;
            Ok(StepKind::Dot {
                lhs: *lhs,
                rhs: *rhs,
                plan,
                shape,
            })
        }
        Op::Reduce {
            operand,
            init,
            dims,
            to_apply,
        } => {
            let shape = instr.shape.array()?.clone();
            let a = arr_shape(comp, *operand)?;
            let init_shape = arr_shape(comp, *init)?;
            if init_shape.elems() != 1 {
                return Err(Error::msg("reduce init value must be a scalar"));
            }
            let rank = a.dims.len();
            for &d in dims {
                if d >= rank {
                    return Err(Error::msg("reduce dimension out of range"));
                }
            }
            interp::check_unique_dims("reduce", "dimensions", dims)?;
            let kept: Vec<usize> = (0..rank).filter(|d| !dims.contains(d)).collect();
            let kept_sizes: Vec<usize> = kept.iter().map(|&d| a.dims[d]).collect();
            let out_elems: usize = kept_sizes.iter().product();
            if out_elems != shape.elems() {
                return Err(Error::msg(format!(
                    "reduce output shape {:?} does not match kept dimensions {kept_sizes:?}",
                    shape.dims
                )));
            }
            let a_strides = a.strides();
            let kept_strides: Vec<usize> = kept.iter().map(|&d| a_strides[d]).collect();
            let kept_offsets = interp::offset_table(&kept_sizes, &kept_strides);
            let red_sizes: Vec<usize> = dims.iter().map(|&d| a.dims[d]).collect();
            let red_strides: Vec<usize> = dims.iter().map(|&d| a_strides[d]).collect();
            let red_offsets = interp::offset_table(&red_sizes, &red_strides);
            if *to_apply >= module.computations.len() {
                return Err(Error::msg("reduce to_apply region out of range"));
            }
            Ok(StepKind::Reduce {
                a: *operand,
                init: *init,
                kept_offsets,
                red_offsets,
                fast: interp::fast_reducer(module, *to_apply),
                to_apply: *to_apply,
                shape,
            })
        }
        Op::Tuple(operands) => Ok(StepKind::MakeTuple(operands.clone())),
        Op::GetTupleElement { operand, index } => Ok(StepKind::Gte {
            a: *operand,
            index: *index,
        }),
    }
}

/// Validate a dot-general and build its offset tables (mirrors the
/// reference evaluator's checks, plus the shared duplicate-dim rules).
#[allow(clippy::too_many_arguments)]
fn build_dot_plan(
    a: &Shape,
    b: &Shape,
    lhs_c: &[usize],
    rhs_c: &[usize],
    lhs_b: &[usize],
    rhs_b: &[usize],
    out: &Shape,
) -> Result<DotPlan> {
    if lhs_c.len() != rhs_c.len() || lhs_b.len() != rhs_b.len() {
        return Err(Error::msg("dot contracting/batch dimension arity mismatch"));
    }
    interp::check_dot_dims(lhs_c, rhs_c, lhs_b, rhs_b)?;
    for &d in lhs_c.iter().chain(lhs_b) {
        if d >= a.dims.len() {
            return Err(Error::msg(format!("dot lhs dimension {d} out of range")));
        }
    }
    for &d in rhs_c.iter().chain(rhs_b) {
        if d >= b.dims.len() {
            return Err(Error::msg(format!("dot rhs dimension {d} out of range")));
        }
    }
    for (&l, &r) in lhs_c.iter().zip(rhs_c) {
        if a.dims[l] != b.dims[r] {
            return Err(Error::msg(format!(
                "dot contracting sizes differ: lhs dim {l} = {}, rhs dim {r} = {}",
                a.dims[l], b.dims[r]
            )));
        }
    }
    for (&l, &r) in lhs_b.iter().zip(rhs_b) {
        if a.dims[l] != b.dims[r] {
            return Err(Error::msg("dot batch sizes differ"));
        }
    }
    let a_strides = a.strides();
    let b_strides = b.strides();
    let pick = |dims: &[usize], from: &[usize]| -> Vec<usize> {
        dims.iter().map(|&d| from[d]).collect()
    };
    let lhs_free: Vec<usize> = (0..a.dims.len())
        .filter(|d| !lhs_c.contains(d) && !lhs_b.contains(d))
        .collect();
    let rhs_free: Vec<usize> = (0..b.dims.len())
        .filter(|d| !rhs_c.contains(d) && !rhs_b.contains(d))
        .collect();
    let batch_sizes = pick(lhs_b, &a.dims);
    let contract_sizes = pick(lhs_c, &a.dims);
    let lf_sizes = pick(&lhs_free, &a.dims);
    let rf_sizes = pick(&rhs_free, &b.dims);
    let bl = interp::offset_table(&batch_sizes, &pick(lhs_b, &a_strides));
    let br = interp::offset_table(&batch_sizes, &pick(rhs_b, &b_strides));
    let cl = interp::offset_table(&contract_sizes, &pick(lhs_c, &a_strides));
    let cr = interp::offset_table(&contract_sizes, &pick(rhs_c, &b_strides));
    let lf = interp::offset_table(&lf_sizes, &pick(&lhs_free, &a_strides));
    let rf = interp::offset_table(&rf_sizes, &pick(&rhs_free, &b_strides));
    let expected = bl.len() * lf.len() * rf.len();
    if expected != out.elems() {
        return Err(Error::msg(format!(
            "dot output shape {:?} has {} elements, computation produces {expected}",
            out.dims,
            out.elems()
        )));
    }
    let rf_contiguous = rf.iter().enumerate().all(|(i, &o)| o == i);
    let flops = 2usize
        .saturating_mul(expected)
        .saturating_mul(cl.len().max(1));
    Ok(DotPlan {
        bl,
        br,
        cl,
        cr,
        lf,
        rf,
        rf_contiguous,
        out_len: expected,
        flops,
    })
}
