//! Native HLO-text interpreter behind the `xla-rs` PJRT API surface.
//!
//! The coordinator executes every candidate architecture through
//! AOT-compiled HLO artifacts via the PJRT C API. The real bindings
//! (`xla-rs` + the bundled `xla_extension`) require a native XLA build that
//! is not fetchable in offline/CI environments, so this crate provides the
//! exact API *shape* the coordinator compiles against — and, since PR 3,
//! a **working implementation**: a parser for the HLO text format emitted
//! by `python/compile/aot.py` ([`parser`]) and an evaluator over host
//! `Vec<f32>` storage ([`interp`]) covering the op set those artifacts
//! use (dot/dot-general, the elementwise ops, compare/select, broadcast,
//! reshape/transpose/slice/concatenate, reduce, constant, convert,
//! parameter, tuple/get-tuple-element, iota).
//!
//! * every type the coordinator names ([`PjRtClient`], [`PjRtBuffer`],
//!   [`PjRtLoadedExecutable`], [`HloModuleProto`], [`XlaComputation`],
//!   [`Literal`]) keeps the same method signatures as `xla-rs`;
//! * all types are `Send + Sync` (plain data, no FFI handles), which is the
//!   thread-safety contract `snac_pack::eval::ParallelEvaluator` relies on —
//!   real PJRT clients are thread-safe for concurrent `Execute` calls, so a
//!   drop-in replacement keeps that contract;
//! * execution happens in-process: `compile` lowers the module into a
//!   cached execution plan ([`plan`]) and `execute_b` runs the blocked
//!   kernels ([`kernels`]) over it, recycling intermediate buffers through
//!   a per-executable arena. The naive evaluator ([`interp`]) is retained
//!   as the bit-exact reference ([`PjRtLoadedExecutable::execute_b_reference`],
//!   [`set_reference_mode`], `SNAC_XLA_REFERENCE=1`). No native XLA, no JAX.
//!
//! Process-wide knobs: [`set_dot_threads`] sizes the deterministic
//! dot-general thread pool (results are bit-identical at every setting —
//! see `kernels.rs` for the contract), [`alloc_stats`] counts fresh vs
//! arena-recycled buffer allocations for the benches, and
//! [`set_verify_plans`] (`SNAC_XLA_VERIFY=1`, always on in debug builds)
//! makes `compile` statically verify every plan's bounds / liveness /
//! thread-partition / dataflow invariants ([`verify`]) before handing
//! out an executable.
//!
//! See `README.md` in this directory for the supported op set and for how
//! the real PJRT bindings still swap in.

use std::fmt;
use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{Arc, Mutex, OnceLock};

pub mod interp;
pub mod kernels;
pub mod parser;
pub mod plan;
pub mod verify;

use interp::{ArrayValue, Value};
use kernels::Arena;
use parser::{DType, Module, Shape};

pub use kernels::{alloc_stats, dot_threads, reset_alloc_stats, set_dot_threads};
pub use verify::{set_verify_plans, verify_plans, PlanVerifyError};

/// When set (or when `SNAC_XLA_REFERENCE=1` is in the environment),
/// `execute_b` routes through the retained naive reference evaluator
/// instead of the compiled execution plan. Used by the differential CI
/// runs that assert the two paths produce byte-identical outputs.
static FORCE_REFERENCE: AtomicBool = AtomicBool::new(false);
static ENV_REFERENCE: OnceLock<bool> = OnceLock::new();

/// Force (or stop forcing) the reference evaluator for this process.
pub fn set_reference_mode(on: bool) {
    FORCE_REFERENCE.store(on, Ordering::Relaxed);
}

/// Sink receiving sampled per-op timings from planned execution:
/// `(step kind, computation name, duration in µs)`. A plain `fn` pointer
/// so the host crate's tracer can plug in without this crate depending
/// on it.
pub type OpSink = fn(&'static str, &str, u64);

/// Per-op sampling rate: record every Nth executed plan step. 0 = off.
static OP_SAMPLE: AtomicU64 = AtomicU64::new(0);
static OP_SINK: Mutex<Option<OpSink>> = Mutex::new(None);

/// Configure sampled per-op timing: every `sample`-th executed plan step
/// is timed and reported to `sink`. `sample == 0` (or `sink == None`)
/// turns it off — the default, so kernels pay one relaxed load per
/// execution, not per step. Timing is observational only: step results
/// are bit-identical at every setting.
pub fn set_op_trace(sample: u64, sink: Option<OpSink>) {
    *OP_SINK.lock().unwrap_or_else(|e| e.into_inner()) = if sample == 0 { None } else { sink };
    OP_SAMPLE.store(if sink.is_none() { 0 } else { sample }, Ordering::Relaxed);
}

/// The active (sample rate, sink) pair, if per-op tracing is on. Loaded
/// once per plan execution, not per step.
pub(crate) fn op_trace_config() -> Option<(u64, OpSink)> {
    if OP_SAMPLE.load(Ordering::Relaxed) == 0 {
        return None;
    }
    let sample = OP_SAMPLE.load(Ordering::Relaxed);
    let sink = *OP_SINK.lock().unwrap_or_else(|e| e.into_inner());
    sink.filter(|_| sample > 0).map(|s| (sample, s))
}

/// Whether `execute_b` currently uses the reference evaluator.
pub fn reference_mode() -> bool {
    FORCE_REFERENCE.load(Ordering::Relaxed)
        || *ENV_REFERENCE
            .get_or_init(|| std::env::var("SNAC_XLA_REFERENCE").is_ok_and(|v| v == "1"))
}

/// Interpreter/facade error.
#[derive(Debug)]
pub struct Error {
    message: String,
}

impl Error {
    pub(crate) fn msg(message: impl Into<String>) -> Error {
        Error {
            message: message.into(),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Error {}

/// Result type (mirrors `xla_rs::Result`).
pub type Result<T> = std::result::Result<T, Error>;

/// Element types accepted by [`PjRtClient::buffer_from_host_buffer`] and
/// [`Literal::to_vec`]. Host storage is `f32`; other element types convert
/// on the way in/out.
pub trait ElementType: Copy + Send + Sync + 'static {
    /// Convert one element to the interpreter's host storage type.
    fn to_f32(self) -> f32;
    /// Convert one host element back out.
    fn from_f32(v: f32) -> Self;
}

impl ElementType for f32 {
    fn to_f32(self) -> f32 {
        self
    }
    fn from_f32(v: f32) -> f32 {
        v
    }
}

impl ElementType for f64 {
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(v: f32) -> f64 {
        v as f64
    }
}

impl ElementType for i32 {
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(v: f32) -> i32 {
        v as i32
    }
}

impl ElementType for i64 {
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(v: f32) -> i64 {
        v as i64
    }
}

impl ElementType for u8 {
    fn to_f32(self) -> f32 {
        self as f32
    }
    fn from_f32(v: f32) -> u8 {
        v as u8
    }
}

/// A PJRT device handle (the interpreter has exactly one).
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// A parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto {
    module: Arc<Module>,
}

impl HloModuleProto {
    /// Parse an HLO module from its text serialisation on disk.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        if !path.exists() {
            return Err(Error::msg(format!("HLO text file {path:?} does not exist")));
        }
        let text = std::fs::read_to_string(path)
            .map_err(|e| Error::msg(format!("reading {path:?}: {e}")))?;
        Self::from_text(&text)
            .map_err(|e| Error::msg(format!("parsing HLO text {path:?}: {e}")))
    }

    /// Parse an HLO module from in-memory text.
    pub fn from_text(text: &str) -> Result<HloModuleProto> {
        Ok(HloModuleProto {
            module: Arc::new(parser::parse_module(text)?),
        })
    }

    /// Module name from the `HloModule` header.
    pub fn name(&self) -> &str {
        &self.module.name
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    module: Arc<Module>,
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(proto: &HloModuleProto) -> XlaComputation {
        XlaComputation {
            module: Arc::clone(&proto.module),
        }
    }
}

/// A device-side buffer (host memory here).
#[derive(Debug)]
pub struct PjRtBuffer {
    value: Value,
}

impl PjRtBuffer {
    /// Download the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(Literal {
            value: self.value.clone(),
        })
    }
}

/// A host-side literal (possibly a tuple).
#[derive(Debug)]
pub struct Literal {
    value: Value,
}

impl Literal {
    /// Destructure a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self.value {
            Value::Tuple(elems) => Ok(elems
                .into_iter()
                .map(|value| Literal { value })
                .collect()),
            Value::Array(_) => Err(Error::msg("literal is not a tuple")),
        }
    }

    /// Copy the literal out as a flat host vector.
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        let arr = self.value.array()?;
        Ok(arr.data.iter().map(|&v| T::from_f32(v)).collect())
    }
}

/// A compiled, loaded executable: the parsed module, its cached execution
/// plan, and a pool of recycled intermediate buffers.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    module: Arc<Module>,
    plan: plan::ExecPlan,
    pool: BufferPool,
}

/// Recycled intermediate buffers shared by this executable's executions:
/// each `execute_b` seeds its arena from here and drains it back after,
/// so back-to-back calls allocate almost nothing. Concurrent calls simply
/// split the pool (or run fresh) — never block on each other.
#[derive(Debug, Default)]
struct BufferPool {
    free: Mutex<Vec<Vec<f32>>>,
    fresh: AtomicU64,
    reused: AtomicU64,
}

/// Keep at most this many recycled buffers per executable.
const POOL_CAP: usize = 256;

impl BufferPool {
    fn take(&self) -> Vec<Vec<f32>> {
        let mut guard = self.free.lock().unwrap_or_else(|e| e.into_inner());
        std::mem::take(&mut *guard)
    }

    fn put(&self, arena: Arena) {
        let (mut free, fresh, reused) = arena.into_parts();
        self.fresh.fetch_add(fresh, Ordering::Relaxed);
        self.reused.fetch_add(reused, Ordering::Relaxed);
        let mut guard = self.free.lock().unwrap_or_else(|e| e.into_inner());
        if guard.is_empty() {
            free.truncate(POOL_CAP);
            *guard = free;
        } else {
            while guard.len() < POOL_CAP {
                match free.pop() {
                    Some(buf) => guard.push(buf),
                    None => break,
                }
            }
        }
    }
}

impl PjRtLoadedExecutable {
    /// Execute against borrowed input buffers (the leak-free path: inputs
    /// stay owned by the caller and are freed on drop). Runs the compiled
    /// execution plan unless [`reference_mode`] is on.
    pub fn execute_b(&self, args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        if reference_mode() {
            return self.execute_b_reference(args);
        }
        let entry = self.module.entry_computation();
        if args.len() != entry.params.len() {
            return Err(Error::msg(format!(
                "executable takes {} arguments, got {}",
                entry.params.len(),
                args.len()
            )));
        }
        // refcount bumps, not copies: parameters share the caller's storage
        let values: Vec<Value> = args.iter().map(|b| b.value.clone()).collect();
        let mut arena = Arena::with_free(self.pool.take());
        let result = self.plan.execute_entry(&values, &mut arena);
        self.pool.put(arena);
        Ok(vec![vec![PjRtBuffer { value: result? }]])
    }

    /// Execute through the retained naive reference evaluator
    /// ([`interp::evaluate`]) — the bit-exactness oracle for the planned
    /// kernels. Slow; for tests, benches and audits.
    pub fn execute_b_reference(&self, args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        let entry = self.module.entry_computation();
        if args.len() != entry.params.len() {
            return Err(Error::msg(format!(
                "executable takes {} arguments, got {}",
                entry.params.len(),
                args.len()
            )));
        }
        let values: Vec<Value> = args.iter().map(|b| b.value.clone()).collect();
        let result = interp::evaluate(&self.module, self.module.entry, &values)?;
        Ok(vec![vec![PjRtBuffer { value: result }]])
    }

    /// Statically re-verify this executable's compiled plan (bounds,
    /// liveness, thread-partition and dataflow soundness) without
    /// executing it. `compile` already runs this when [`verify_plans`]
    /// is on; this entry point exists for audits and the benches that
    /// measure verification cost per module.
    pub fn verify(&self) -> std::result::Result<(), verify::PlanVerifyError> {
        self.plan.verify()
    }

    /// (fresh, arena-reused) intermediate-buffer allocation counts across
    /// this executable's planned executions.
    pub fn arena_alloc_stats(&self) -> (u64, u64) {
        (
            self.pool.fresh.load(Ordering::Relaxed),
            self.pool.reused.load(Ordering::Relaxed),
        )
    }
}

/// A PJRT client backed by the in-process interpreter.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient { _private: () })
    }

    /// Platform name.
    pub fn platform_name(&self) -> String {
        "interpreter".to_string()
    }

    /// Compile a computation: lower the parsed module into a cached
    /// execution plan (shape/stride tables, liveness, kernel selection).
    /// Malformed modules fail here, naming the offending instruction.
    ///
    /// When [`verify_plans`] is on (always in debug builds, opt-in via
    /// [`set_verify_plans`] / `SNAC_XLA_VERIFY=1` in release), the plan
    /// is also statically verified — bounds, liveness, thread-partition
    /// and dataflow invariants — before an executable is handed out.
    pub fn compile(&self, comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        let plan = plan::ExecPlan::new(Arc::clone(&comp.module))?;
        if verify::verify_plans() {
            plan.verify().map_err(|e| Error::msg(e.to_string()))?;
        }
        Ok(PjRtLoadedExecutable {
            module: Arc::clone(&comp.module),
            plan,
            pool: BufferPool::default(),
        })
    }

    /// Upload a host slice as a device buffer with the given dimensions.
    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        let shape = Shape {
            dtype: DType::F32,
            dims: dims.to_vec(),
        };
        if shape.elems() != data.len() {
            return Err(Error::msg(format!(
                "buffer dims {dims:?} hold {} elements, host slice has {}",
                shape.elems(),
                data.len()
            )));
        }
        let value = ArrayValue::new(shape, data.iter().map(|v| v.to_f32()).collect())?;
        Ok(PjRtBuffer {
            value: Value::Array(value),
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The whole point of the facade contract: the types are shareable
    // across the evaluation thread pool.
    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn facade_types_are_send_sync() {
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<PjRtBuffer>();
        assert_send_sync::<Literal>();
        assert_send_sync::<Error>();
    }

    #[test]
    fn missing_files_and_garbage_error_cleanly() {
        let err = HloModuleProto::from_text_file("/nonexistent/a.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("does not exist"));
        let err = HloModuleProto::from_text("not hlo at all").unwrap_err();
        assert!(!err.to_string().is_empty());
    }

    #[test]
    fn unsupported_opcodes_fail_at_parse_time_with_the_op_name() {
        let text = "HloModule bad\n\nENTRY %main (x: f32[2]) -> f32[2] {\n  \
                    %x = f32[2] parameter(0)\n  \
                    ROOT %r = f32[2] custom-call(%x), custom_call_target=\"foo\"\n}\n";
        let err = HloModuleProto::from_text(text).unwrap_err();
        assert!(err.to_string().contains("custom-call"), "{err}");
    }

    #[test]
    fn end_to_end_scalar_pipeline() {
        // (x + y) * x over f32[2,2], through the full client API
        let text = "HloModule smoke\n\nENTRY %main (x: f32[2,2], y: f32[2,2]) -> f32[2,2] {\n  \
                    %x = f32[2,2]{1,0} parameter(0)\n  \
                    %y = f32[2,2]{1,0} parameter(1)\n  \
                    %s = f32[2,2]{1,0} add(f32[2,2] %x, f32[2,2] %y)\n  \
                    ROOT %p = f32[2,2]{1,0} multiply(%s, %x)\n}\n";
        let proto = HloModuleProto::from_text(text).unwrap();
        let comp = XlaComputation::from_proto(&proto);
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&comp).unwrap();
        let x = client
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0, 3.0, 4.0], &[2, 2], None)
            .unwrap();
        let y = client
            .buffer_from_host_buffer::<f32>(&[10.0, 20.0, 30.0, 40.0], &[2, 2], None)
            .unwrap();
        let out = exe.execute_b(&[x, y]).unwrap();
        let lit = out[0][0].to_literal_sync().unwrap();
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![11.0, 44.0, 99.0, 176.0]);
    }

    #[test]
    fn argument_arity_and_shape_are_validated() {
        let text = "HloModule v\n\nENTRY %main (x: f32[3]) -> f32[3] {\n  \
                    ROOT %x = f32[3] parameter(0)\n}\n";
        let proto = HloModuleProto::from_text(text).unwrap();
        let client = PjRtClient::cpu().unwrap();
        let exe = client.compile(&XlaComputation::from_proto(&proto)).unwrap();
        assert!(exe.execute_b(&[]).unwrap_err().to_string().contains("takes 1"));
        let wrong = client
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[2], None)
            .unwrap();
        let err = exe.execute_b(&[wrong]).unwrap_err();
        assert!(err.to_string().contains("parameter 0"), "{err}");
    }
}
