//! Offline facade of the `xla-rs` PJRT API surface used by `snac-pack`.
//!
//! The coordinator executes every candidate architecture through
//! AOT-compiled HLO artifacts via the PJRT C API. The real bindings
//! (`xla-rs` + the bundled `xla_extension`) require a native XLA build that
//! is not fetchable in offline/CI environments, so this crate provides the
//! exact API *shape* the coordinator compiles against:
//!
//! * every type the coordinator names ([`PjRtClient`], [`PjRtBuffer`],
//!   [`PjRtLoadedExecutable`], [`HloModuleProto`], [`XlaComputation`],
//!   [`Literal`]) with the same method signatures;
//! * all types are `Send + Sync` (plain data, no FFI handles), which is the
//!   thread-safety contract `snac_pack::eval::ParallelEvaluator` relies on —
//!   real PJRT clients are thread-safe for concurrent `Execute` calls, so a
//!   drop-in replacement keeps that contract;
//! * every operation that would need the native runtime returns a clear
//!   [`Error`] instead, so `Runtime::load` fails fast with an actionable
//!   message while everything host-side (search, surrogate features, HLS
//!   simulator, reports, all artifact-gated tests) builds and runs.
//!
//! See `README.md` in this directory for how to swap in the real bindings.

use std::fmt;
use std::path::Path;

/// Facade error: the native PJRT runtime is not linked into this build.
#[derive(Debug)]
pub struct Error {
    message: String,
}

impl Error {
    fn unavailable(op: &str) -> Error {
        Error {
            message: format!(
                "{op}: the XLA PJRT runtime is not available in this build \
                 (the `xla` dependency is the offline facade; see \
                 rust/xla/README.md to link the real bindings)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.message)
    }
}

impl std::error::Error for Error {}

/// Facade result type (mirrors `xla_rs::Result`).
pub type Result<T> = std::result::Result<T, Error>;

/// Element types accepted by [`PjRtClient::buffer_from_host_buffer`].
pub trait ElementType: Copy + Send + Sync + 'static {}
impl ElementType for f32 {}
impl ElementType for f64 {}
impl ElementType for i32 {}
impl ElementType for i64 {}
impl ElementType for u8 {}

/// A PJRT device handle.
#[derive(Debug, Clone, Copy)]
pub struct PjRtDevice;

/// A parsed HLO module (text interchange format).
#[derive(Debug)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    /// Parse an HLO module from its text serialisation on disk.
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<HloModuleProto> {
        let path = path.as_ref();
        // Validate what we can host-side so missing-artifact errors stay
        // precise even without the native parser.
        if !path.exists() {
            return Err(Error {
                message: format!("HLO text file {path:?} does not exist"),
            });
        }
        Err(Error::unavailable("parsing HLO text"))
    }
}

/// An XLA computation ready for compilation.
#[derive(Debug)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    /// Wrap a parsed HLO module.
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// A device-side buffer.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    /// Download the buffer to a host literal.
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("downloading buffer"))
    }
}

/// A host-side literal (possibly a tuple).
#[derive(Debug)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Destructure a tuple literal into its leaves.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("untupling literal"))
    }

    /// Copy the literal out as a flat host vector.
    pub fn to_vec<T: ElementType>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("reading literal"))
    }
}

/// A compiled, loaded executable.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    /// Execute against borrowed input buffers (the leak-free path: inputs
    /// stay owned by the caller and are freed on drop).
    pub fn execute_b(&self, _args: &[PjRtBuffer]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("executing"))
    }
}

/// A PJRT client.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    /// Create a CPU client.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("creating PJRT CPU client"))
    }

    /// Platform name, e.g. `cpu`.
    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    /// Compile a computation for this client's platform.
    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("compiling"))
    }

    /// Upload a host slice as a device buffer with the given dimensions.
    pub fn buffer_from_host_buffer<T: ElementType>(
        &self,
        _data: &[T],
        _dims: &[usize],
        _device: Option<&PjRtDevice>,
    ) -> Result<PjRtBuffer> {
        Err(Error::unavailable("uploading buffer"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    // The whole point of the facade: the types are shareable across the
    // evaluation thread pool.
    fn assert_send_sync<T: Send + Sync>() {}

    #[test]
    fn facade_types_are_send_sync() {
        assert_send_sync::<PjRtClient>();
        assert_send_sync::<PjRtLoadedExecutable>();
        assert_send_sync::<PjRtBuffer>();
        assert_send_sync::<Literal>();
        assert_send_sync::<Error>();
    }

    #[test]
    fn unavailable_operations_error_cleanly() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"));
        let err = HloModuleProto::from_text_file("/nonexistent/a.hlo.txt").unwrap_err();
        assert!(err.to_string().contains("does not exist"));
    }
}
