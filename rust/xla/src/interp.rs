//! Reference evaluator for parsed HLO modules.
//!
//! Values are host-side `f32` buffers (`pred` is stored as 0.0/1.0,
//! integers as their rounded value — exact below 2^24, far beyond anything
//! the SNAC-Pack artifacts index). Tuples are trees of arrays. Each
//! instruction is evaluated once in program order (HLO text is
//! topologically sorted by construction), so evaluation is a single linear
//! pass with no recursion except `reduce`'s `to_apply` regions.
//!
//! Performance notes: since the execution-plan refactor this module is the
//! **naive reference evaluator**, not the hot path. `PjRtClient::compile`
//! lowers the module into a cached [`crate::plan::ExecPlan`] that
//! precomputes per executable what this file re-derives per call (output
//! shapes, offset tables, `fast_reducer` recognition, last-use liveness)
//! and executes through the blocked kernels in [`crate::kernels`].
//! `evaluate` is retained on purpose, with its per-op loops unchanged:
//!
//! * the differential harness (`tests/differential.rs`) asserts the
//!   planned kernels are **bit-exact** against this evaluator, so keep the
//!   two implementations independent — do not "share" kernel loops between
//!   them or the comparison stops meaning anything;
//! * `PjRtLoadedExecutable::execute_b_reference` (and the
//!   `SNAC_XLA_REFERENCE=1` escape hatch) route production executions
//!   through here when auditing a planned-kernel result.
//!
//! Accumulation-order contract shared with the planned kernels: for every
//! output element of `dot`/`reduce`, terms are folded left-to-right in
//! row-major order of the contracted coordinates, and `dot` skips lhs
//! terms that are exactly `0.0` (documented deviation: XLA would propagate
//! `0·inf`/`0·NaN`). The planned kernels preserve both properties exactly,
//! at every `threads` setting — see `plan.rs` for how.

use std::sync::Arc;

use crate::parser::{BinaryOp, CmpDir, Computation, DType, Module, Op, Shape, UnaryOp};
use crate::{Error, Result};

/// A host-side array value. The payload is `Arc`-shared so that parameter
/// passing, `reshape`/`copy`/same-width `convert`, and tuple construction
/// are refcount bumps instead of deep copies.
#[derive(Debug, Clone)]
pub struct ArrayValue {
    pub shape: Shape,
    pub data: Arc<Vec<f32>>,
}

impl ArrayValue {
    /// New array, validating the element count.
    pub fn new(shape: Shape, data: Vec<f32>) -> Result<ArrayValue> {
        ArrayValue::from_arc(shape, Arc::new(data))
    }

    /// New array over shared storage, validating the element count.
    pub fn from_arc(shape: Shape, data: Arc<Vec<f32>>) -> Result<ArrayValue> {
        if shape.elems() != data.len() {
            return Err(Error::msg(format!(
                "shape {:?} holds {} elements, got {}",
                shape.dims,
                shape.elems(),
                data.len()
            )));
        }
        Ok(ArrayValue { shape, data })
    }

    pub(crate) fn scalar(v: f32, dtype: DType) -> ArrayValue {
        ArrayValue {
            shape: Shape { dtype, dims: vec![] },
            data: Arc::new(vec![v]),
        }
    }

    fn is_scalar(&self) -> bool {
        self.data.len() == 1 && self.shape.dims.iter().all(|&d| d == 1)
    }
}

/// An array or a tuple of values (tuples nest, matching HLO).
#[derive(Debug, Clone)]
pub enum Value {
    Array(ArrayValue),
    Tuple(Vec<Value>),
}

impl Value {
    /// The array, or an error for tuples.
    pub fn array(&self) -> Result<&ArrayValue> {
        match self {
            Value::Array(a) => Ok(a),
            Value::Tuple(_) => Err(Error::msg("expected an array value, found a tuple")),
        }
    }
}

/// Run a computation of `module` on the given arguments.
pub fn evaluate(module: &Module, comp_idx: usize, args: &[Value]) -> Result<Value> {
    let comp = &module.computations[comp_idx];
    if args.len() != comp.params.len() {
        return Err(Error::msg(format!(
            "computation `{}` takes {} parameters, got {} arguments",
            comp.name,
            comp.params.len(),
            args.len()
        )));
    }
    let mut slots: Vec<Option<Value>> = vec![None; comp.instrs.len()];
    for (idx, instr) in comp.instrs.iter().enumerate() {
        let value = eval_instr(module, comp, idx, &slots, args).map_err(|e| {
            Error::msg(format!(
                "evaluating `%{}` in computation `{}`: {e}",
                instr.name, comp.name
            ))
        })?;
        slots[idx] = Some(value);
    }
    slots[comp.root]
        .take()
        .ok_or_else(|| Error::msg("root instruction produced no value"))
}

fn get<'a>(slots: &'a [Option<Value>], idx: usize) -> Result<&'a Value> {
    slots
        .get(idx)
        .and_then(|v| v.as_ref())
        .ok_or_else(|| Error::msg("operand evaluated out of order"))
}

fn get_array<'a>(slots: &'a [Option<Value>], idx: usize) -> Result<&'a ArrayValue> {
    get(slots, idx)?.array()
}

fn out_shape(comp: &Computation, idx: usize) -> Result<&Shape> {
    comp.instrs[idx].shape.array()
}

fn eval_instr(
    module: &Module,
    comp: &Computation,
    idx: usize,
    slots: &[Option<Value>],
    args: &[Value],
) -> Result<Value> {
    let instr = &comp.instrs[idx];
    match &instr.op {
        Op::Parameter(n) => {
            let arg = args
                .get(*n)
                .ok_or_else(|| Error::msg(format!("missing argument {n}")))?;
            if let (Ok(decl), Value::Array(a)) = (instr.shape.array(), arg) {
                if decl.elems() != a.data.len() {
                    return Err(Error::msg(format!(
                        "parameter {n} expects shape {:?} ({} elements), argument has {}",
                        decl.dims,
                        decl.elems(),
                        a.data.len()
                    )));
                }
                // dims must match too: equal element counts with different
                // dims (e.g. a transposed manifest entry) would otherwise
                // flow into downstream ops as silently wrong numerics
                if decl.dims != a.shape.dims {
                    return Err(Error::msg(format!(
                        "parameter {n} expects dims {:?}, argument uploaded as {:?}",
                        decl.dims, a.shape.dims
                    )));
                }
            }
            Ok(arg.clone())
        }
        Op::Constant(data) => {
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(ArrayValue::new(shape, data.clone())?))
        }
        Op::Unary(op, a) => {
            let a = get_array(slots, *a)?;
            let data = a.data.iter().map(|&v| unary(*op, v)).collect();
            Ok(Value::Array(ArrayValue {
                shape: out_shape(comp, idx)?.clone(),
                data,
            }))
        }
        Op::Binary(op, a, b) => {
            let (a, b) = (get_array(slots, *a)?, get_array(slots, *b)?);
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(binary_elementwise(*op, a, b, shape)?))
        }
        Op::Compare { dir, lhs, rhs } => {
            let (a, b) = (get_array(slots, *lhs)?, get_array(slots, *rhs)?);
            let shape = out_shape(comp, idx)?.clone();
            let out = zip_broadcast(a, b, shape, |x, y| compare_scalar(*dir, x, y))?;
            Ok(Value::Array(out))
        }
        Op::Select {
            pred,
            on_true,
            on_false,
        } => {
            let p = get_array(slots, *pred)?;
            let t = get_array(slots, *on_true)?;
            let f = get_array(slots, *on_false)?;
            if t.data.len() != f.data.len() {
                return Err(Error::msg("select branches have mismatched sizes"));
            }
            let shape = out_shape(comp, idx)?.clone();
            if p.is_scalar() {
                let picked = if p.data[0] != 0.0 { t } else { f };
                return ArrayValue::from_arc(shape, Arc::clone(&picked.data)).map(Value::Array);
            }
            if p.data.len() != t.data.len() {
                return Err(Error::msg("select predicate has mismatched size"));
            }
            let data: Vec<f32> = p
                .data
                .iter()
                .zip(t.data.iter().zip(f.data.iter()))
                .map(|(&p, (&t, &f))| if p != 0.0 { t } else { f })
                .collect();
            Ok(Value::Array(ArrayValue::new(shape, data)?))
        }
        Op::Broadcast { operand, dims } => {
            let a = get_array(slots, *operand)?;
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(broadcast(a, dims, shape)?))
        }
        Op::Reshape(operand) | Op::Copy(operand) => {
            let a = get_array(slots, *operand)?;
            let shape = out_shape(comp, idx)?.clone();
            ArrayValue::from_arc(shape, Arc::clone(&a.data)).map(Value::Array)
        }
        Op::Convert(operand) => {
            let a = get_array(slots, *operand)?;
            let shape = out_shape(comp, idx)?.clone();
            if shape.dtype.is_integer() {
                let data = a.data.iter().map(|v| v.trunc()).collect();
                ArrayValue::new(shape, data).map(Value::Array)
            } else if shape.dtype == DType::Pred {
                let data = a
                    .data
                    .iter()
                    .map(|&v| if v != 0.0 { 1.0 } else { 0.0 })
                    .collect();
                ArrayValue::new(shape, data).map(Value::Array)
            } else {
                // host storage is f32 either way: width-only conversion
                ArrayValue::from_arc(shape, Arc::clone(&a.data)).map(Value::Array)
            }
        }
        Op::Transpose { operand, perm } => {
            let a = get_array(slots, *operand)?;
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(transpose(a, perm, shape)?))
        }
        Op::Slice {
            operand,
            starts,
            limits,
            strides,
        } => {
            let a = get_array(slots, *operand)?;
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(slice(a, starts, limits, strides, shape)?))
        }
        Op::Concat { operands, dim } => {
            let parts: Vec<&ArrayValue> = operands
                .iter()
                .map(|&i| get_array(slots, i))
                .collect::<Result<_>>()?;
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(concat(&parts, *dim, shape)?))
        }
        Op::Iota { dim } => {
            let shape = out_shape(comp, idx)?.clone();
            if *dim >= shape.dims.len() {
                return Err(Error::msg(format!(
                    "iota_dimension {dim} out of range for shape {:?}",
                    shape.dims
                )));
            }
            let strides = shape.strides();
            let n = shape.elems();
            let (size, stride) = (shape.dims[*dim], strides[*dim]);
            let mut data = vec![0.0f32; n];
            for (i, v) in data.iter_mut().enumerate() {
                *v = ((i / stride) % size) as f32;
            }
            ArrayValue::new(shape, data).map(Value::Array)
        }
        Op::Dot {
            lhs,
            rhs,
            lhs_contracting,
            rhs_contracting,
            lhs_batch,
            rhs_batch,
        } => {
            let (a, b) = (get_array(slots, *lhs)?, get_array(slots, *rhs)?);
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(dot_general(
                a,
                b,
                lhs_contracting,
                rhs_contracting,
                lhs_batch,
                rhs_batch,
                shape,
            )?))
        }
        Op::Reduce {
            operand,
            init,
            dims,
            to_apply,
        } => {
            let a = get_array(slots, *operand)?;
            let init = get_array(slots, *init)?;
            if init.data.len() != 1 {
                return Err(Error::msg("reduce init value must be a scalar"));
            }
            let shape = out_shape(comp, idx)?.clone();
            Ok(Value::Array(reduce(
                module, *to_apply, a, init.data[0], dims, shape,
            )?))
        }
        Op::Tuple(operands) => {
            let elems = operands
                .iter()
                .map(|&i| get(slots, i).cloned())
                .collect::<Result<Vec<_>>>()?;
            Ok(Value::Tuple(elems))
        }
        Op::GetTupleElement { operand, index } => match get(slots, *operand)? {
            Value::Tuple(elems) => elems
                .get(*index)
                .cloned()
                .ok_or_else(|| Error::msg(format!("tuple has no element {index}"))),
            Value::Array(_) => Err(Error::msg("get-tuple-element of a non-tuple")),
        },
    }
}

pub(crate) fn compare_scalar(dir: CmpDir, x: f32, y: f32) -> f32 {
    let r = match dir {
        CmpDir::Eq => x == y,
        CmpDir::Ne => x != y,
        CmpDir::Lt => x < y,
        CmpDir::Le => x <= y,
        CmpDir::Gt => x > y,
        CmpDir::Ge => x >= y,
    };
    if r {
        1.0
    } else {
        0.0
    }
}

pub(crate) fn unary(op: UnaryOp, v: f32) -> f32 {
    match op {
        UnaryOp::Negate => -v,
        UnaryOp::Abs => v.abs(),
        UnaryOp::Exp => v.exp(),
        UnaryOp::Expm1 => v.exp_m1(),
        UnaryOp::Log => v.ln(),
        UnaryOp::Log1p => v.ln_1p(),
        UnaryOp::Sqrt => v.sqrt(),
        UnaryOp::Rsqrt => 1.0 / v.sqrt(),
        UnaryOp::Tanh => v.tanh(),
        UnaryOp::Floor => v.floor(),
        UnaryOp::Ceil => v.ceil(),
        UnaryOp::RoundAfz => v.round(),
        UnaryOp::RoundEven => {
            // ties-to-even without `round_ties_even` (stable only ≥ 1.77):
            // `round` rounds half away from zero; pull exact .5 ties back
            // to the even neighbour.
            let r = v.round();
            if (r - v).abs() == 0.5 && r % 2.0 != 0.0 {
                r - (r - v).signum()
            } else {
                r
            }
        }
        UnaryOp::Sign => {
            if v > 0.0 {
                1.0
            } else if v < 0.0 {
                -1.0
            } else {
                v // preserves ±0 and propagates NaN like XLA's sign
            }
        }
        UnaryOp::Cos => v.cos(),
        UnaryOp::Sin => v.sin(),
        UnaryOp::Logistic => 1.0 / (1.0 + (-v).exp()),
        UnaryOp::Not => {
            if v != 0.0 {
                0.0
            } else {
                1.0
            }
        }
    }
}

pub(crate) fn binary_scalar(op: BinaryOp, x: f32, y: f32) -> f32 {
    match op {
        BinaryOp::Add => x + y,
        BinaryOp::Sub => x - y,
        BinaryOp::Mul => x * y,
        BinaryOp::Div => x / y,
        BinaryOp::Max => x.max(y),
        BinaryOp::Min => x.min(y),
        BinaryOp::Pow => x.powf(y),
        BinaryOp::Rem => x % y,
        BinaryOp::And => {
            if x != 0.0 && y != 0.0 {
                1.0
            } else {
                0.0
            }
        }
        BinaryOp::Or => {
            if x != 0.0 || y != 0.0 {
                1.0
            } else {
                0.0
            }
        }
        BinaryOp::Xor => {
            if (x != 0.0) != (y != 0.0) {
                1.0
            } else {
                0.0
            }
        }
    }
}

/// Elementwise combine with implicit *scalar* broadcasting: HLO proper
/// requires explicit `broadcast` for rank mismatches, but accepting a
/// rank-0 operand directly keeps the hand-authored fixtures readable (see
/// tests/fixtures/README.md) and matches what an explicit broadcast would
/// compute.
fn zip_broadcast(
    a: &ArrayValue,
    b: &ArrayValue,
    shape: Shape,
    f: impl Fn(f32, f32) -> f32,
) -> Result<ArrayValue> {
    let data: Vec<f32> = if a.data.len() == b.data.len() {
        a.data
            .iter()
            .zip(b.data.iter())
            .map(|(&x, &y)| f(x, y))
            .collect()
    } else if a.is_scalar() {
        let x = a.data[0];
        b.data.iter().map(|&y| f(x, y)).collect()
    } else if b.is_scalar() {
        let y = b.data[0];
        a.data.iter().map(|&x| f(x, y)).collect()
    } else {
        return Err(Error::msg(format!(
            "elementwise operands have mismatched sizes {} vs {}",
            a.data.len(),
            b.data.len()
        )));
    };
    ArrayValue::new(shape, data)
}

fn binary_elementwise(
    op: BinaryOp,
    a: &ArrayValue,
    b: &ArrayValue,
    shape: Shape,
) -> Result<ArrayValue> {
    zip_broadcast(a, b, shape, |x, y| binary_scalar(op, x, y))
}

/// Reject duplicate entries in an op's dimension list with an error naming
/// the op: duplicates would double-count strides in the offset tables
/// (`reduce` used to panic with index-out-of-bounds, `broadcast` silently
/// computed a wrong operand index).
pub(crate) fn check_unique_dims(op: &str, list: &str, dims: &[usize]) -> Result<()> {
    for (i, &d) in dims.iter().enumerate() {
        if dims[..i].contains(&d) {
            return Err(Error::msg(format!(
                "{op} {list} {dims:?} contain dimension {d} more than once"
            )));
        }
    }
    Ok(())
}

/// XLA's broadcast rule: `dimensions={...}` must be strictly increasing.
pub(crate) fn check_broadcast_dims_increasing(dims: &[usize]) -> Result<()> {
    if dims.windows(2).any(|w| w[0] >= w[1]) {
        return Err(Error::msg(format!(
            "broadcast dimensions {dims:?} must be strictly increasing"
        )));
    }
    Ok(())
}

/// `broadcast(operand), dimensions={...}`: `dims[i]` is the output
/// dimension that operand dimension `i` maps to.
fn broadcast(a: &ArrayValue, dims: &[usize], shape: Shape) -> Result<ArrayValue> {
    if dims.len() != a.shape.dims.len() {
        return Err(Error::msg(format!(
            "broadcast dimensions {:?} do not match operand rank {}",
            dims,
            a.shape.dims.len()
        )));
    }
    check_broadcast_dims_increasing(dims)?;
    let out_strides = shape.strides();
    for (i, &d) in dims.iter().enumerate() {
        if d >= shape.dims.len() || shape.dims[d] != a.shape.dims[i] {
            return Err(Error::msg(format!(
                "broadcast maps operand dim {i} (size {}) to output dim {d} of {:?}",
                a.shape.dims[i], shape.dims
            )));
        }
    }
    let n = shape.elems();
    let mut data = vec![0.0f32; n];
    if a.data.len() == 1 {
        data.fill(a.data[0]);
        return ArrayValue::new(shape, data);
    }
    // operand index = Σ_i out_coord[dims[i]] * a_stride[i]
    let a_strides = a.shape.strides();
    for (out_idx, v) in data.iter_mut().enumerate() {
        let mut a_idx = 0usize;
        for (i, &d) in dims.iter().enumerate() {
            let coord = (out_idx / out_strides[d]) % shape.dims[d];
            a_idx += coord * a_strides[i];
        }
        *v = a.data[a_idx];
    }
    ArrayValue::new(shape, data)
}

/// `transpose(operand), dimensions={perm}`: output dim `i` is operand dim
/// `perm[i]`.
fn transpose(a: &ArrayValue, perm: &[usize], shape: Shape) -> Result<ArrayValue> {
    if perm.len() != a.shape.dims.len() {
        return Err(Error::msg("transpose permutation rank mismatch"));
    }
    let mut seen = vec![false; perm.len()];
    for (i, &p) in perm.iter().enumerate() {
        if p >= a.shape.dims.len() || std::mem::replace(&mut seen[p], true) {
            return Err(Error::msg(format!("transpose dimensions {perm:?} are not a permutation")));
        }
        if shape.dims.get(i) != Some(&a.shape.dims[p]) {
            return Err(Error::msg(format!(
                "transpose output dim {i} should be {} (operand dim {p}), declared {:?}",
                a.shape.dims[p], shape.dims
            )));
        }
    }
    let out_strides = shape.strides();
    let a_strides = a.shape.strides();
    let n = shape.elems();
    let mut data = vec![0.0f32; n];
    for (out_idx, v) in data.iter_mut().enumerate() {
        let mut a_idx = 0usize;
        for (i, &p) in perm.iter().enumerate() {
            let coord = (out_idx / out_strides[i]) % shape.dims[i];
            a_idx += coord * a_strides[p];
        }
        *v = a.data[a_idx];
    }
    ArrayValue::new(shape, data)
}

fn slice(
    a: &ArrayValue,
    starts: &[usize],
    limits: &[usize],
    strides: &[usize],
    shape: Shape,
) -> Result<ArrayValue> {
    let rank = a.shape.dims.len();
    if starts.len() != rank || limits.len() != rank || strides.len() != rank {
        return Err(Error::msg("slice spec rank mismatch"));
    }
    for d in 0..rank {
        if limits[d] > a.shape.dims[d] || starts[d] > limits[d] || strides[d] == 0 {
            return Err(Error::msg(format!(
                "slice [{}:{}:{}] out of bounds for dim {d} (size {})",
                starts[d], limits[d], strides[d], a.shape.dims[d]
            )));
        }
        let produced = (limits[d] - starts[d]).div_ceil(strides[d]);
        if shape.dims.get(d) != Some(&produced) {
            return Err(Error::msg(format!(
                "slice [{}:{}:{}] produces {produced} elements along dim {d}, \
                 declared shape says {:?}",
                starts[d], limits[d], strides[d], shape.dims
            )));
        }
    }
    let out_strides = shape.strides();
    let a_strides = a.shape.strides();
    let n = shape.elems();
    let mut data = vec![0.0f32; n];
    for (out_idx, v) in data.iter_mut().enumerate() {
        let mut a_idx = 0usize;
        for d in 0..rank {
            let coord = (out_idx / out_strides[d]) % shape.dims[d];
            a_idx += (starts[d] + coord * strides[d]) * a_strides[d];
        }
        *v = a.data[a_idx];
    }
    ArrayValue::new(shape, data)
}

fn concat(parts: &[&ArrayValue], dim: usize, shape: Shape) -> Result<ArrayValue> {
    if parts.is_empty() {
        return Err(Error::msg("concatenate of zero operands"));
    }
    let rank = parts[0].shape.dims.len();
    if dim >= rank {
        return Err(Error::msg("concatenate dimension out of range"));
    }
    // every operand must agree on all dimensions except `dim`
    for (i, p) in parts.iter().enumerate() {
        if p.shape.dims.len() != rank
            || p.shape
                .dims
                .iter()
                .zip(&parts[0].shape.dims)
                .enumerate()
                .any(|(d, (a, b))| d != dim && a != b)
        {
            return Err(Error::msg(format!(
                "concatenate operand {i} has shape {:?}, incompatible with {:?} along dim {dim}",
                p.shape.dims, parts[0].shape.dims
            )));
        }
    }
    // outer = product of dims before `dim`; inner = product after
    let outer: usize = parts[0].shape.dims[..dim].iter().product();
    let inner: usize = parts[0].shape.dims[dim + 1..].iter().product();
    let mut data = Vec::with_capacity(shape.elems());
    for o in 0..outer {
        for p in parts {
            let rows = p.shape.dims[dim];
            let chunk = rows * inner;
            data.extend_from_slice(&p.data[o * chunk..(o + 1) * chunk]);
        }
    }
    ArrayValue::new(shape, data)
}

/// Additive offset table for a subset of dimensions: enumerates the
/// coordinates of `dims` (by size) in row-major order and returns each
/// combination's contribution Σ coord·stride to a flat index.
///
/// Shared dim-math contract with [`crate::plan`] and [`crate::verify`]:
/// every entry is bounded by `Σ (size_i − 1)·stride_i` (the value the
/// static verifier proves in-bounds), a zero size anywhere yields an
/// *empty* table (nothing is ever read), and all-empty `sizes` yield the
/// single offset `0`.
pub(crate) fn offset_table(sizes: &[usize], strides: &[usize]) -> Vec<usize> {
    let total: usize = sizes.iter().product();
    let mut out = Vec::with_capacity(total.max(1));
    out.push(0);
    for (&size, &stride) in sizes.iter().zip(strides) {
        let prev = std::mem::take(&mut out);
        out = Vec::with_capacity(prev.len() * size);
        for base in prev {
            for c in 0..size {
                out.push(base + c * stride);
            }
        }
    }
    out
}

#[allow(clippy::too_many_arguments)]
fn dot_general(
    a: &ArrayValue,
    b: &ArrayValue,
    lhs_c: &[usize],
    rhs_c: &[usize],
    lhs_b: &[usize],
    rhs_b: &[usize],
    shape: Shape,
) -> Result<ArrayValue> {
    if lhs_c.len() != rhs_c.len() || lhs_b.len() != rhs_b.len() {
        return Err(Error::msg("dot contracting/batch dimension arity mismatch"));
    }
    check_dot_dims(lhs_c, rhs_c, lhs_b, rhs_b)?;
    for &d in lhs_c.iter().chain(lhs_b) {
        if d >= a.shape.dims.len() {
            return Err(Error::msg(format!("dot lhs dimension {d} out of range")));
        }
    }
    for &d in rhs_c.iter().chain(rhs_b) {
        if d >= b.shape.dims.len() {
            return Err(Error::msg(format!("dot rhs dimension {d} out of range")));
        }
    }
    let a_strides = a.shape.strides();
    let b_strides = b.shape.strides();
    let pick = |dims: &[usize], from: &[usize]| -> Vec<usize> {
        dims.iter().map(|&d| from[d]).collect()
    };
    for (&l, &r) in lhs_c.iter().zip(rhs_c) {
        if a.shape.dims[l] != b.shape.dims[r] {
            return Err(Error::msg(format!(
                "dot contracting sizes differ: lhs dim {l} = {}, rhs dim {r} = {}",
                a.shape.dims[l], b.shape.dims[r]
            )));
        }
    }
    for (&l, &r) in lhs_b.iter().zip(rhs_b) {
        if a.shape.dims[l] != b.shape.dims[r] {
            return Err(Error::msg("dot batch sizes differ"));
        }
    }
    let lhs_free: Vec<usize> = (0..a.shape.dims.len())
        .filter(|d| !lhs_c.contains(d) && !lhs_b.contains(d))
        .collect();
    let rhs_free: Vec<usize> = (0..b.shape.dims.len())
        .filter(|d| !rhs_c.contains(d) && !rhs_b.contains(d))
        .collect();

    let batch_sizes = pick(lhs_b, &a.shape.dims);
    let contract_sizes = pick(lhs_c, &a.shape.dims);
    let lf_sizes = pick(&lhs_free, &a.shape.dims);
    let rf_sizes = pick(&rhs_free, &b.shape.dims);

    let bl = offset_table(&batch_sizes, &pick(lhs_b, &a_strides));
    let br = offset_table(&batch_sizes, &pick(rhs_b, &b_strides));
    let cl = offset_table(&contract_sizes, &pick(lhs_c, &a_strides));
    let cr = offset_table(&contract_sizes, &pick(rhs_c, &b_strides));
    let lf = offset_table(&lf_sizes, &pick(&lhs_free, &a_strides));
    let rf = offset_table(&rf_sizes, &pick(&rhs_free, &b_strides));

    let expected: usize = bl.len() * lf.len() * rf.len();
    if expected != shape.elems() {
        return Err(Error::msg(format!(
            "dot output shape {:?} has {} elements, computation produces {expected}",
            shape.dims,
            shape.elems()
        )));
    }
    let mut data = vec![0.0f32; expected];
    let nrf = rf.len();
    // contiguous fast path: rhs free offsets are 0,1,2,... (free dims are
    // the trailing dims) — the overwhelmingly common case here
    let rf_contiguous = rf.iter().enumerate().all(|(i, &o)| o == i);
    for (bi, (&bl_off, &br_off)) in bl.iter().zip(&br).enumerate() {
        for (li, &lf_off) in lf.iter().enumerate() {
            let row_start = (bi * lf.len() + li) * nrf;
            let row = &mut data[row_start..row_start + nrf];
            for (&cl_off, &cr_off) in cl.iter().zip(&cr) {
                let x = a.data[bl_off + lf_off + cl_off];
                if x == 0.0 {
                    // Skipping zero lhs terms is a large win for the
                    // unit/prune-masked supernet (whole masked columns are
                    // zero). Documented deviation: XLA would propagate
                    // 0·inf/0·NaN as NaN; a run whose rhs already holds
                    // non-finite values is diverged either way.
                    continue;
                }
                let rbase = br_off + cr_off;
                if rf_contiguous {
                    let rrow = &b.data[rbase..rbase + nrf];
                    for (acc, &y) in row.iter_mut().zip(rrow) {
                        *acc += x * y;
                    }
                } else {
                    for (acc, &roff) in row.iter_mut().zip(&rf) {
                        *acc += x * b.data[rbase + roff];
                    }
                }
            }
        }
    }
    ArrayValue::new(shape, data)
}

/// Duplicate / overlap validation shared by both evaluators: every dim may
/// appear at most once across an operand's batch + contracting lists.
pub(crate) fn check_dot_dims(
    lhs_c: &[usize],
    rhs_c: &[usize],
    lhs_b: &[usize],
    rhs_b: &[usize],
) -> Result<()> {
    check_unique_dims("dot", "lhs_contracting_dims", lhs_c)?;
    check_unique_dims("dot", "rhs_contracting_dims", rhs_c)?;
    check_unique_dims("dot", "lhs_batch_dims", lhs_b)?;
    check_unique_dims("dot", "rhs_batch_dims", rhs_b)?;
    for &d in lhs_b {
        if lhs_c.contains(&d) {
            return Err(Error::msg(format!(
                "dot lhs dimension {d} appears in both batch and contracting lists"
            )));
        }
    }
    for &d in rhs_b {
        if rhs_c.contains(&d) {
            return Err(Error::msg(format!(
                "dot rhs dimension {d} appears in both batch and contracting lists"
            )));
        }
    }
    Ok(())
}

/// A `to_apply` region recognised as a plain scalar binary op. The
/// swapped-operand form (`op(%p1, %p0)`) only qualifies when `op` is
/// commutative — `subtract(%p1, %p0)` must fall through to the general
/// interpreter, which evaluates the region as written.
pub(crate) fn fast_reducer(module: &Module, comp_idx: usize) -> Option<BinaryOp> {
    let comp = module.computations.get(comp_idx)?;
    if comp.params.len() != 2 {
        return None;
    }
    let root = &comp.instrs[comp.root];
    if let Op::Binary(op, a, b) = &root.op {
        let is_params = |x: usize, y: usize| {
            matches!(comp.instrs[x].op, Op::Parameter(0))
                && matches!(comp.instrs[y].op, Op::Parameter(1))
        };
        let commutative = matches!(
            op,
            BinaryOp::Add
                | BinaryOp::Mul
                | BinaryOp::Max
                | BinaryOp::Min
                | BinaryOp::And
                | BinaryOp::Or
                | BinaryOp::Xor
        );
        if is_params(*a, *b) || (commutative && is_params(*b, *a)) {
            return Some(*op);
        }
    }
    None
}

fn reduce(
    module: &Module,
    to_apply: usize,
    a: &ArrayValue,
    init: f32,
    dims: &[usize],
    shape: Shape,
) -> Result<ArrayValue> {
    let rank = a.shape.dims.len();
    for &d in dims {
        if d >= rank {
            return Err(Error::msg("reduce dimension out of range"));
        }
    }
    check_unique_dims("reduce", "dimensions", dims)?;
    let kept: Vec<usize> = (0..rank).filter(|d| !dims.contains(d)).collect();
    let kept_sizes: Vec<usize> = kept.iter().map(|&d| a.shape.dims[d]).collect();
    let out_elems: usize = kept_sizes.iter().product();
    if out_elems != shape.elems() {
        return Err(Error::msg(format!(
            "reduce output shape {:?} does not match kept dimensions {kept_sizes:?}",
            shape.dims
        )));
    }
    let a_strides = a.shape.strides();
    let kept_offsets = offset_table(&kept_sizes, &kept.iter().map(|&d| a_strides[d]).collect::<Vec<_>>());
    let red_sizes: Vec<usize> = dims.iter().map(|&d| a.shape.dims[d]).collect();
    let red_offsets = offset_table(&red_sizes, &dims.iter().map(|&d| a_strides[d]).collect::<Vec<_>>());

    let fast = fast_reducer(module, to_apply);
    let mut data = vec![init; out_elems];
    match fast {
        Some(op) => {
            for (out, &ko) in data.iter_mut().zip(&kept_offsets) {
                let mut acc = *out;
                for &ro in &red_offsets {
                    acc = binary_scalar(op, acc, a.data[ko + ro]);
                }
                *out = acc;
            }
        }
        None => {
            // general path: interpret the region per element
            let dtype = a.shape.dtype;
            for (out, &ko) in data.iter_mut().zip(&kept_offsets) {
                let mut acc = *out;
                for &ro in &red_offsets {
                    let r = evaluate(
                        module,
                        to_apply,
                        &[
                            Value::Array(ArrayValue::scalar(acc, dtype)),
                            Value::Array(ArrayValue::scalar(a.data[ko + ro], dtype)),
                        ],
                    )?;
                    acc = r.array()?.data[0];
                }
                *out = acc;
            }
        }
    }
    ArrayValue::new(shape, data)
}
