//! Recursive-descent parser for the HLO *text* format.
//!
//! Covers the subset of the grammar that `python/compile/aot.py` emits
//! (`jax.jit(...).lower()` → StableHLO → `XlaComputation.as_hlo_text()`)
//! plus the hand-authored fixtures under `tests/fixtures/`:
//!
//! ```text
//! HloModule name[, module attributes...]
//!
//! %helper (a: f32[], b: f32[]) -> f32[] {
//!   %a = f32[] parameter(0)
//!   %b = f32[] parameter(1)
//!   ROOT %add.1 = f32[] add(f32[] %a, f32[] %b)
//! }
//!
//! ENTRY %main (Arg_0.1: f32[2,3]) -> (f32[2,3]) {
//!   %Arg_0.1 = f32[2,3]{1,0} parameter(0)
//!   ...
//!   ROOT %tuple.9 = (f32[2,3]) tuple(%Arg_0.1)
//! }
//! ```
//!
//! Layout annotations (`{1,0}`), inline operand shapes, and decorative
//! attributes (`metadata=`, `sharding=`, `backend_config=`, …) are parsed
//! and discarded. Unknown *opcodes* are a hard error at parse time so an
//! artifact outside the interpreter's op set fails at `Runtime::load`
//! with the op's name instead of producing garbage numerics later.

use crate::{Error, Result};

/// Element type of an array shape. Everything is *stored* as `f32`
/// host-side; the tag drives `convert`, comparison results (`pred`) and
/// integer rounding semantics.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DType {
    F32,
    F64,
    Bf16,
    F16,
    Pred,
    S8,
    S32,
    S64,
    U8,
    U32,
    U64,
}

impl DType {
    fn from_str(s: &str) -> Option<DType> {
        Some(match s {
            "f32" => DType::F32,
            "f64" => DType::F64,
            "bf16" => DType::Bf16,
            "f16" => DType::F16,
            "pred" => DType::Pred,
            "s8" => DType::S8,
            "s32" => DType::S32,
            "s64" => DType::S64,
            "u8" => DType::U8,
            "u32" => DType::U32,
            "u64" => DType::U64,
            _ => return None,
        })
    }

    /// Integer types round toward zero on `convert`.
    pub fn is_integer(self) -> bool {
        matches!(
            self,
            DType::S8 | DType::S32 | DType::S64 | DType::U8 | DType::U32 | DType::U64
        )
    }
}

/// An array shape: element type + dimensions (scalar = empty dims).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Shape {
    pub dtype: DType,
    pub dims: Vec<usize>,
}

impl Shape {
    /// Total element count.
    pub fn elems(&self) -> usize {
        self.dims.iter().product()
    }

    /// Row-major strides.
    pub fn strides(&self) -> Vec<usize> {
        let mut s = vec![1usize; self.dims.len()];
        for i in (0..self.dims.len().saturating_sub(1)).rev() {
            s[i] = s[i + 1] * self.dims[i + 1];
        }
        s
    }
}

/// Declared result shape of an instruction (tuples appear only on `tuple`
/// roots in our artifacts, but nesting is represented anyway).
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum ShapeDecl {
    Array(Shape),
    Tuple(Vec<ShapeDecl>),
}

impl ShapeDecl {
    /// The array shape, or an error for tuples.
    pub fn array(&self) -> Result<&Shape> {
        match self {
            ShapeDecl::Array(s) => Ok(s),
            ShapeDecl::Tuple(_) => Err(Error::msg("expected array shape, found tuple")),
        }
    }
}

/// Comparison direction of a `compare` instruction.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CmpDir {
    Eq,
    Ne,
    Lt,
    Le,
    Gt,
    Ge,
}

/// Elementwise unary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum UnaryOp {
    Negate,
    Abs,
    Exp,
    Expm1,
    Log,
    Log1p,
    Sqrt,
    Rsqrt,
    Tanh,
    Floor,
    Ceil,
    RoundAfz,
    RoundEven,
    Sign,
    Cos,
    Sin,
    Logistic,
    Not,
}

/// Elementwise binary operations.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BinaryOp {
    Add,
    Sub,
    Mul,
    Div,
    Max,
    Min,
    Pow,
    Rem,
    And,
    Or,
    Xor,
}

/// One parsed instruction. Operand values are instruction indices within
/// the owning computation.
#[derive(Debug, Clone)]
pub enum Op {
    Parameter(usize),
    Constant(Vec<f32>),
    Unary(UnaryOp, usize),
    Binary(BinaryOp, usize, usize),
    Compare {
        dir: CmpDir,
        lhs: usize,
        rhs: usize,
    },
    Select {
        pred: usize,
        on_true: usize,
        on_false: usize,
    },
    Broadcast {
        operand: usize,
        dims: Vec<usize>,
    },
    Reshape(usize),
    Copy(usize),
    Convert(usize),
    Transpose {
        operand: usize,
        perm: Vec<usize>,
    },
    Slice {
        operand: usize,
        starts: Vec<usize>,
        limits: Vec<usize>,
        strides: Vec<usize>,
    },
    Concat {
        operands: Vec<usize>,
        dim: usize,
    },
    Iota {
        dim: usize,
    },
    Dot {
        lhs: usize,
        rhs: usize,
        lhs_contracting: Vec<usize>,
        rhs_contracting: Vec<usize>,
        lhs_batch: Vec<usize>,
        rhs_batch: Vec<usize>,
    },
    Reduce {
        operand: usize,
        init: usize,
        dims: Vec<usize>,
        /// Computation index into [`Module::computations`].
        to_apply: usize,
    },
    Tuple(Vec<usize>),
    GetTupleElement {
        operand: usize,
        index: usize,
    },
}

/// A named instruction with its declared result shape.
#[derive(Debug, Clone)]
pub struct Instr {
    pub name: String,
    pub shape: ShapeDecl,
    pub op: Op,
}

/// One computation (the entry or a `to_apply` region).
#[derive(Debug, Clone)]
pub struct Computation {
    pub name: String,
    pub instrs: Vec<Instr>,
    /// Index of the ROOT instruction.
    pub root: usize,
    /// Instruction index of parameter `i`, for each `i`.
    pub params: Vec<usize>,
}

/// A parsed HLO module.
#[derive(Debug, Clone)]
pub struct Module {
    pub name: String,
    pub computations: Vec<Computation>,
    /// Index of the ENTRY computation.
    pub entry: usize,
}

impl Module {
    /// The ENTRY computation.
    pub fn entry_computation(&self) -> &Computation {
        &self.computations[self.entry]
    }
}

// ---------------------------------------------------------------------------
// cursor utilities
// ---------------------------------------------------------------------------

struct Cursor<'a> {
    s: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn new(s: &'a str) -> Cursor<'a> {
        Cursor { s: s.as_bytes(), pos: 0 }
    }

    fn peek(&self) -> Option<u8> {
        self.s.get(self.pos).copied()
    }

    fn bump(&mut self) -> Option<u8> {
        let c = self.peek();
        if c.is_some() {
            self.pos += 1;
        }
        c
    }

    fn skip_ws(&mut self) {
        while matches!(self.peek(), Some(b' ') | Some(b'\t')) {
            self.pos += 1;
        }
    }

    fn eat(&mut self, c: u8) -> bool {
        self.skip_ws();
        if self.peek() == Some(c) {
            self.pos += 1;
            true
        } else {
            false
        }
    }

    fn expect(&mut self, c: u8, what: &str) -> Result<()> {
        if self.eat(c) {
            Ok(())
        } else {
            Err(Error::msg(format!(
                "expected `{}` {what} at byte {} of line",
                c as char, self.pos
            )))
        }
    }

    /// Identifier: letters, digits, `_`, `.`, `-` (opcodes use hyphens,
    /// instruction names use dots).
    fn ident(&mut self) -> String {
        self.skip_ws();
        let start = self.pos;
        while let Some(c) = self.peek() {
            if c.is_ascii_alphanumeric() || matches!(c, b'_' | b'.' | b'-') {
                self.pos += 1;
            } else {
                break;
            }
        }
        String::from_utf8_lossy(&self.s[start..self.pos]).into_owned()
    }

    fn integer(&mut self) -> Result<usize> {
        self.skip_ws();
        let start = self.pos;
        while self.peek().is_some_and(|c| c.is_ascii_digit()) {
            self.pos += 1;
        }
        if start == self.pos {
            return Err(Error::msg(format!("expected integer at byte {start} of line")));
        }
        std::str::from_utf8(&self.s[start..self.pos])
            .ok()
            .and_then(|t| t.parse().ok())
            .ok_or_else(|| Error::msg("bad integer"))
    }

    /// Consume a balanced `{...}` block (quote-aware), returning its inner
    /// text. The cursor must be at `{`.
    fn balanced_braces(&mut self) -> Result<String> {
        self.skip_ws();
        self.expect(b'{', "opening attribute block")?;
        let start = self.pos;
        let mut depth = 1usize;
        let mut in_str = false;
        while let Some(c) = self.bump() {
            match c {
                b'"' => in_str = !in_str,
                b'\\' if in_str => {
                    self.bump();
                }
                b'{' if !in_str => depth += 1,
                b'}' if !in_str => {
                    depth -= 1;
                    if depth == 0 {
                        return Ok(String::from_utf8_lossy(&self.s[start..self.pos - 1])
                            .into_owned());
                    }
                }
                _ => {}
            }
        }
        Err(Error::msg("unterminated `{...}` block"))
    }

    /// Consume the raw parenthesized section after an opcode, tracking
    /// nesting; the cursor must be at `(`. Returns the inner text.
    fn balanced_parens(&mut self) -> Result<String> {
        self.skip_ws();
        self.expect(b'(', "operand list")?;
        let start = self.pos;
        let mut depth = 1usize;
        let mut in_str = false;
        while let Some(c) = self.bump() {
            match c {
                b'"' => in_str = !in_str,
                b'\\' if in_str => {
                    self.bump();
                }
                b'(' if !in_str => depth += 1,
                b')' if !in_str => {
                    depth -= 1;
                    if depth == 0 {
                        return Ok(String::from_utf8_lossy(&self.s[start..self.pos - 1])
                            .into_owned());
                    }
                }
                _ => {}
            }
        }
        Err(Error::msg("unterminated `(...)` operand list"))
    }
}

// ---------------------------------------------------------------------------
// shape parsing
// ---------------------------------------------------------------------------

fn parse_array_shape(cur: &mut Cursor<'_>) -> Result<Shape> {
    let dtype_tok = cur.ident();
    let dtype = DType::from_str(&dtype_tok)
        .ok_or_else(|| Error::msg(format!("unsupported element type `{dtype_tok}`")))?;
    cur.expect(b'[', "shape dimensions")?;
    let mut dims = Vec::new();
    cur.skip_ws();
    if cur.peek() != Some(b']') {
        loop {
            dims.push(cur.integer()?);
            if !cur.eat(b',') {
                break;
            }
        }
    }
    cur.expect(b']', "closing shape dimensions")?;
    // optional layout annotation, e.g. `{1,0}` — parsed and discarded
    cur.skip_ws();
    if cur.peek() == Some(b'{') {
        cur.balanced_braces()?;
    }
    Ok(Shape { dtype, dims })
}

fn parse_shape_decl(cur: &mut Cursor<'_>) -> Result<ShapeDecl> {
    cur.skip_ws();
    if cur.peek() == Some(b'(') {
        cur.bump();
        let mut elems = Vec::new();
        cur.skip_ws();
        if cur.peek() != Some(b')') {
            loop {
                elems.push(parse_shape_decl(cur)?);
                if !cur.eat(b',') {
                    break;
                }
            }
        }
        cur.expect(b')', "closing tuple shape")?;
        Ok(ShapeDecl::Tuple(elems))
    } else {
        Ok(ShapeDecl::Array(parse_array_shape(cur)?))
    }
}

// ---------------------------------------------------------------------------
// attribute parsing
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct Attrs {
    raw: Vec<(String, String)>,
}

impl Attrs {
    fn get(&self, key: &str) -> Option<&str> {
        self.raw
            .iter()
            .find(|(k, _)| k == key)
            .map(|(_, v)| v.as_str())
    }

    /// `{1,0}` (or a bare integer) → vec of integers.
    fn int_list(&self, key: &str) -> Result<Vec<usize>> {
        let v = self
            .get(key)
            .ok_or_else(|| Error::msg(format!("missing attribute `{key}`")))?;
        parse_int_list(v)
    }

    fn int(&self, key: &str) -> Result<usize> {
        let v = self
            .get(key)
            .ok_or_else(|| Error::msg(format!("missing attribute `{key}`")))?;
        v.trim()
            .parse()
            .map_err(|_| Error::msg(format!("attribute `{key}`: bad integer `{v}`")))
    }
}

fn parse_int_list(v: &str) -> Result<Vec<usize>> {
    let inner = v.trim().trim_start_matches('{').trim_end_matches('}');
    let mut out = Vec::new();
    for tok in inner.split(',') {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        out.push(
            tok.parse()
                .map_err(|_| Error::msg(format!("bad integer `{tok}` in `{v}`")))?,
        );
    }
    Ok(out)
}

/// `{[0:1], [0:128]}` or `{[0:10:2]}` → (starts, limits, strides).
fn parse_slice_spec(v: &str) -> Result<(Vec<usize>, Vec<usize>, Vec<usize>)> {
    let inner = v.trim().trim_start_matches('{').trim_end_matches('}');
    let (mut starts, mut limits, mut strides) = (Vec::new(), Vec::new(), Vec::new());
    for part in inner.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let part = part.trim_start_matches('[').trim_end_matches(']');
        let nums: Vec<&str> = part.split(':').collect();
        if nums.len() < 2 || nums.len() > 3 {
            return Err(Error::msg(format!("bad slice range `[{part}]`")));
        }
        let parse = |t: &str| -> Result<usize> {
            t.trim()
                .parse()
                .map_err(|_| Error::msg(format!("bad slice bound `{t}`")))
        };
        starts.push(parse(nums[0])?);
        limits.push(parse(nums[1])?);
        strides.push(if nums.len() == 3 { parse(nums[2])? } else { 1 });
    }
    Ok((starts, limits, strides))
}

fn parse_attrs(cur: &mut Cursor<'_>) -> Result<Attrs> {
    let mut attrs = Attrs::default();
    loop {
        cur.skip_ws();
        if !cur.eat(b',') {
            break;
        }
        let key = cur.ident();
        if key.is_empty() {
            return Err(Error::msg("empty attribute name"));
        }
        cur.expect(b'=', "attribute value")?;
        cur.skip_ws();
        let value = match cur.peek() {
            Some(b'{') => {
                let inner = cur.balanced_braces()?;
                format!("{{{inner}}}")
            }
            Some(b'"') => {
                cur.bump();
                let start = cur.pos;
                while let Some(c) = cur.bump() {
                    if c == b'\\' {
                        cur.bump();
                    } else if c == b'"' {
                        break;
                    }
                }
                String::from_utf8_lossy(&cur.s[start..cur.pos.saturating_sub(1)]).into_owned()
            }
            _ => {
                // bare token (direction=GT, index=0, to_apply=%add.1)
                let start = cur.pos;
                while let Some(c) = cur.peek() {
                    if c == b',' {
                        break;
                    }
                    cur.pos += 1;
                }
                String::from_utf8_lossy(&cur.s[start..cur.pos])
                    .trim()
                    .to_string()
            }
        };
        attrs.raw.push((key, value));
    }
    Ok(attrs)
}

// ---------------------------------------------------------------------------
// literal parsing (constant payloads)
// ---------------------------------------------------------------------------

fn parse_literal(raw: &str, name: &str) -> Result<Vec<f32>> {
    let mut out = Vec::new();
    // Flatten the nested-brace form by scanning numeric / boolean tokens.
    for tok in raw.split(|c: char| matches!(c, '{' | '}' | ',' | ' ' | '\t')) {
        let tok = tok.trim();
        if tok.is_empty() {
            continue;
        }
        let v = match tok {
            "true" => 1.0,
            "false" => 0.0,
            "inf" => f32::INFINITY,
            "-inf" => f32::NEG_INFINITY,
            "nan" | "-nan" => f32::NAN,
            _ => tok.parse::<f32>().map_err(|_| {
                Error::msg(format!("constant `%{name}`: bad literal token `{tok}`"))
            })?,
        };
        out.push(v);
    }
    if out.is_empty() {
        return Err(Error::msg(format!("constant `%{name}` has an empty literal")));
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// instruction / module parsing
// ---------------------------------------------------------------------------

fn strip_pct(tok: &str) -> &str {
    tok.trim().trim_start_matches('%')
}

/// Split a raw operand section at top-level commas and resolve each
/// operand's *last* whitespace token (inline shapes are discarded).
fn resolve_operands(
    raw: &str,
    by_name: &std::collections::HashMap<String, usize>,
    instr: &str,
) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    let mut depth = 0i32;
    let mut start = 0usize;
    let bytes = raw.as_bytes();
    let mut parts: Vec<&str> = Vec::new();
    for (i, &c) in bytes.iter().enumerate() {
        match c {
            b'{' | b'[' | b'(' => depth += 1,
            b'}' | b']' | b')' => depth -= 1,
            b',' if depth == 0 => {
                parts.push(&raw[start..i]);
                start = i + 1;
            }
            _ => {}
        }
    }
    parts.push(&raw[start..]);
    for part in parts {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let name = strip_pct(part.split_whitespace().last().unwrap_or(part));
        let idx = by_name.get(name).ok_or_else(|| {
            Error::msg(format!("instruction `%{instr}` references unknown operand `%{name}`"))
        })?;
        out.push(*idx);
    }
    Ok(out)
}

fn unary_opcode(op: &str) -> Option<UnaryOp> {
    Some(match op {
        "negate" => UnaryOp::Negate,
        "abs" => UnaryOp::Abs,
        "exponential" => UnaryOp::Exp,
        "exponential-minus-one" => UnaryOp::Expm1,
        "log" => UnaryOp::Log,
        "log-plus-one" => UnaryOp::Log1p,
        "sqrt" => UnaryOp::Sqrt,
        "rsqrt" => UnaryOp::Rsqrt,
        "tanh" => UnaryOp::Tanh,
        "floor" => UnaryOp::Floor,
        "ceil" => UnaryOp::Ceil,
        "round-nearest-afz" => UnaryOp::RoundAfz,
        "round-nearest-even" => UnaryOp::RoundEven,
        "sign" => UnaryOp::Sign,
        "cosine" => UnaryOp::Cos,
        "sine" => UnaryOp::Sin,
        "logistic" => UnaryOp::Logistic,
        "not" => UnaryOp::Not,
        _ => return None,
    })
}

fn binary_opcode(op: &str) -> Option<BinaryOp> {
    Some(match op {
        "add" => BinaryOp::Add,
        "subtract" => BinaryOp::Sub,
        "multiply" => BinaryOp::Mul,
        "divide" => BinaryOp::Div,
        "maximum" => BinaryOp::Max,
        "minimum" => BinaryOp::Min,
        "power" => BinaryOp::Pow,
        "remainder" => BinaryOp::Rem,
        "and" => BinaryOp::And,
        "or" => BinaryOp::Or,
        "xor" => BinaryOp::Xor,
        _ => return None,
    })
}

fn compare_dir(s: &str) -> Result<CmpDir> {
    Ok(match s {
        "EQ" => CmpDir::Eq,
        "NE" => CmpDir::Ne,
        "LT" => CmpDir::Lt,
        "LE" => CmpDir::Le,
        "GT" => CmpDir::Gt,
        "GE" => CmpDir::Ge,
        other => return Err(Error::msg(format!("unknown compare direction `{other}`"))),
    })
}

struct PendingComputation {
    name: String,
    instrs: Vec<Instr>,
    /// `(instr index, to_apply computation name)` fix-ups.
    apply_fixups: Vec<(usize, String)>,
    root: Option<usize>,
    by_name: std::collections::HashMap<String, usize>,
}

/// Parse one instruction line into the pending computation.
fn parse_instruction(line: &str, comp: &mut PendingComputation) -> Result<()> {
    let mut cur = Cursor::new(line);
    cur.skip_ws();
    let mut is_root = false;
    if cur.peek() == Some(b'%') {
        cur.bump();
    }
    // the first identifier is either the ROOT marker or the name itself
    let mut name = cur.ident();
    if name == "ROOT" {
        is_root = true;
        cur.skip_ws();
        if cur.peek() == Some(b'%') {
            cur.bump();
        }
        name = cur.ident();
    }
    if name.is_empty() {
        return Err(Error::msg(format!("bad instruction line `{}`", line.trim())));
    }
    cur.expect(b'=', "instruction assignment")?;
    let shape = parse_shape_decl(&mut cur)?;
    let opcode = cur.ident();
    if opcode.is_empty() {
        return Err(Error::msg(format!("missing opcode in `{}`", line.trim())));
    }
    let raw_operands = cur.balanced_parens()?;
    let attrs = parse_attrs(&mut cur)?;

    let idx = comp.instrs.len();
    let operands = |n: usize| -> Result<Vec<usize>> {
        let ops = resolve_operands(&raw_operands, &comp.by_name, &name)?;
        if ops.len() != n {
            return Err(Error::msg(format!(
                "`{opcode}` (%{name}) expects {n} operands, found {}",
                ops.len()
            )));
        }
        Ok(ops)
    };

    let op = match opcode.as_str() {
        "parameter" => {
            let n: usize = raw_operands.trim().parse().map_err(|_| {
                Error::msg(format!("parameter `%{name}`: bad index `{raw_operands}`"))
            })?;
            Op::Parameter(n)
        }
        "constant" => Op::Constant(parse_literal(&raw_operands, &name)?),
        "compare" => {
            let ops = operands(2)?;
            Op::Compare {
                dir: compare_dir(
                    attrs
                        .get("direction")
                        .ok_or_else(|| Error::msg("compare missing `direction`"))?,
                )?,
                lhs: ops[0],
                rhs: ops[1],
            }
        }
        "select" => {
            let ops = operands(3)?;
            Op::Select {
                pred: ops[0],
                on_true: ops[1],
                on_false: ops[2],
            }
        }
        "broadcast" => Op::Broadcast {
            operand: operands(1)?[0],
            dims: attrs.int_list("dimensions").unwrap_or_default(),
        },
        "reshape" => Op::Reshape(operands(1)?[0]),
        "copy" => Op::Copy(operands(1)?[0]),
        "convert" => Op::Convert(operands(1)?[0]),
        "transpose" => Op::Transpose {
            operand: operands(1)?[0],
            perm: attrs.int_list("dimensions")?,
        },
        "slice" => {
            let spec = attrs
                .get("slice")
                .ok_or_else(|| Error::msg("slice missing `slice={...}`"))?;
            let (starts, limits, strides) = parse_slice_spec(spec)?;
            Op::Slice {
                operand: operands(1)?[0],
                starts,
                limits,
                strides,
            }
        }
        "concatenate" => {
            let ops = resolve_operands(&raw_operands, &comp.by_name, &name)?;
            let dims = attrs.int_list("dimensions")?;
            if dims.len() != 1 {
                return Err(Error::msg("concatenate needs exactly one dimension"));
            }
            Op::Concat {
                operands: ops,
                dim: dims[0],
            }
        }
        "iota" => Op::Iota {
            dim: attrs.int("iota_dimension")?,
        },
        "dot" => {
            let ops = operands(2)?;
            Op::Dot {
                lhs: ops[0],
                rhs: ops[1],
                lhs_contracting: attrs.int_list("lhs_contracting_dims").unwrap_or_default(),
                rhs_contracting: attrs.int_list("rhs_contracting_dims").unwrap_or_default(),
                lhs_batch: attrs.int_list("lhs_batch_dims").unwrap_or_default(),
                rhs_batch: attrs.int_list("rhs_batch_dims").unwrap_or_default(),
            }
        }
        "reduce" => {
            let ops = operands(2)?;
            let apply = attrs
                .get("to_apply")
                .ok_or_else(|| Error::msg("reduce missing `to_apply`"))?;
            comp.apply_fixups
                .push((idx, strip_pct(apply).to_string()));
            Op::Reduce {
                operand: ops[0],
                init: ops[1],
                dims: attrs.int_list("dimensions")?,
                to_apply: usize::MAX, // patched in `finish_module`
            }
        }
        "tuple" => Op::Tuple(resolve_operands(&raw_operands, &comp.by_name, &name)?),
        "get-tuple-element" => Op::GetTupleElement {
            operand: operands(1)?[0],
            index: attrs.int("index")?,
        },
        other => {
            if let Some(u) = unary_opcode(other) {
                Op::Unary(u, operands(1)?[0])
            } else if let Some(b) = binary_opcode(other) {
                let ops = operands(2)?;
                Op::Binary(b, ops[0], ops[1])
            } else {
                return Err(Error::msg(format!(
                    "unsupported HLO opcode `{other}` (instruction `%{name}`) — \
                     the interpreter covers the op set emitted by \
                     python/compile/aot.py; see rust/xla/README.md"
                )));
            }
        }
    };

    if comp.by_name.insert(name.clone(), idx).is_some() {
        return Err(Error::msg(format!(
            "duplicate instruction name `%{name}` — later operand references \
             would silently bind to the wrong definition"
        )));
    }
    if is_root {
        comp.root = Some(idx);
    }
    comp.instrs.push(Instr { name, shape, op });
    Ok(())
}

fn finish_computation(pending: PendingComputation) -> Result<Computation> {
    if pending.instrs.is_empty() {
        return Err(Error::msg(format!("computation `{}` is empty", pending.name)));
    }
    let root = pending.root.unwrap_or(pending.instrs.len() - 1);
    // parameter table: index i → instruction
    let mut params: Vec<Option<usize>> = Vec::new();
    for (i, instr) in pending.instrs.iter().enumerate() {
        if let Op::Parameter(n) = instr.op {
            if params.len() <= n {
                params.resize(n + 1, None);
            }
            if params[n].replace(i).is_some() {
                return Err(Error::msg(format!(
                    "computation `{}` declares parameter {n} twice",
                    pending.name
                )));
            }
        }
    }
    let params: Vec<usize> = params
        .into_iter()
        .enumerate()
        .map(|(n, p)| {
            p.ok_or_else(|| {
                Error::msg(format!(
                    "computation `{}` is missing parameter {n}",
                    pending.name
                ))
            })
        })
        .collect::<Result<_>>()?;
    Ok(Computation {
        name: pending.name,
        instrs: pending.instrs,
        root,
        params,
    })
}

/// Parse an HLO module from its text serialisation.
pub fn parse_module(text: &str) -> Result<Module> {
    // strip /* ... */ comments (some dump modes interleave them)
    let text = strip_block_comments(text);

    let mut module_name = String::from("module");
    let mut pendings: Vec<PendingComputation> = Vec::new();
    let mut current: Option<PendingComputation> = None;
    let mut entry_name: Option<String> = None;

    for (lineno, raw_line) in text.lines().enumerate() {
        let line = raw_line.trim();
        if line.is_empty() || line.starts_with("//") {
            continue;
        }
        let err_ctx = |e: Error| Error::msg(format!("line {}: {e}", lineno + 1));
        if line.starts_with("HloModule") {
            module_name = line["HloModule".len()..]
                .trim()
                .split([',', ' '])
                .next()
                .unwrap_or("module")
                .to_string();
            continue;
        }
        if current.is_none() {
            // computation header: `[ENTRY ]%name (...) -> ... {`
            if !line.ends_with('{') {
                return Err(Error::msg(format!(
                    "line {}: expected computation header, found `{line}`",
                    lineno + 1
                )));
            }
            let mut rest = line;
            let is_entry = if let Some(r) = rest.strip_prefix("ENTRY") {
                rest = r.trim_start();
                true
            } else {
                false
            };
            let name = strip_pct(rest.split(['(', ' ']).next().unwrap_or("")).to_string();
            if name.is_empty() {
                return Err(Error::msg(format!(
                    "line {}: computation header has no name",
                    lineno + 1
                )));
            }
            if is_entry {
                entry_name = Some(name.clone());
            }
            current = Some(PendingComputation {
                name,
                instrs: Vec::new(),
                apply_fixups: Vec::new(),
                root: None,
                by_name: std::collections::HashMap::new(),
            });
            continue;
        }
        if line == "}" {
            let pending = current.take().expect("inside computation");
            pendings.push(pending);
            continue;
        }
        let comp = current.as_mut().expect("inside computation");
        parse_instruction(line, comp).map_err(err_ctx)?;
    }
    if current.is_some() {
        return Err(Error::msg("unterminated computation (missing `}`)"));
    }
    if pendings.is_empty() {
        return Err(Error::msg("no computations found in HLO text"));
    }

    // resolve computation order + to_apply references
    let names: Vec<String> = pendings.iter().map(|p| p.name.clone()).collect();
    let find = |n: &str| -> Result<usize> {
        names
            .iter()
            .position(|c| c == n)
            .ok_or_else(|| Error::msg(format!("to_apply references unknown computation `{n}`")))
    };
    let entry = match entry_name {
        Some(n) => find(&n)?,
        // single-computation modules may omit ENTRY
        None if pendings.len() == 1 => 0,
        None => return Err(Error::msg("no ENTRY computation found")),
    };
    let mut computations = Vec::with_capacity(pendings.len());
    for pending in pendings {
        let apply: Vec<(usize, usize)> = pending
            .apply_fixups
            .iter()
            .map(|(i, n)| Ok((*i, find(n)?)))
            .collect::<Result<_>>()?;
        let mut comp = finish_computation(pending)?;
        for (instr_idx, comp_idx) in apply {
            if let Op::Reduce { to_apply, .. } = &mut comp.instrs[instr_idx].op {
                *to_apply = comp_idx;
            }
        }
        computations.push(comp);
    }
    Ok(Module {
        name: module_name,
        computations,
        entry,
    })
}

fn strip_block_comments(text: &str) -> String {
    let mut out = String::with_capacity(text.len());
    let mut rest = text;
    while let Some(start) = rest.find("/*") {
        out.push_str(&rest[..start]);
        match rest[start..].find("*/") {
            Some(end) => rest = &rest[start + end + 2..],
            None => {
                rest = "";
                break;
            }
        }
    }
    out.push_str(rest);
    out
}
