//! Hot kernels behind the execution plans in [`crate::plan`].
//!
//! Everything here is written against flat `&[f32]` slices with all shape
//! work done once at plan time:
//!
//! * [`Arena`] — per-execution buffer recycling. Plans know each slot's
//!   last use, so intermediates are returned here the moment they die and
//!   the next allocation of any size reuses the storage
//!   (`Arc::try_unwrap` guarantees we never recycle a buffer the caller —
//!   or an aliasing `reshape` — still holds).
//! * [`GatherPlan`] — one strided-copy engine for broadcast / transpose /
//!   slice. The per-element `div`/`mod` coordinate math of the reference
//!   evaluator is replaced by an odometer walk with precomputed per-dim
//!   steps, and the innermost contiguous run is `copy_from_slice` /
//!   `fill`.
//! * [`DotPlan`] — cache-blocked dot-general with optional deterministic
//!   multithreading. Work is partitioned over *output rows only*
//!   (batch × lhs-free), so every output element is accumulated by exactly
//!   one thread in exactly the reference order, including the lhs
//!   zero-skip. Results are bit-identical at every thread count.
//!
//! The process-wide knobs ([`set_dot_threads`], [`alloc_stats`]) live here
//! and are re-exported from the crate root.

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

/// Requested dot-general thread count: 1 = serial (the default),
/// 0 = one per available core, n = exactly n.
static DOT_THREADS: AtomicUsize = AtomicUsize::new(1);

/// Process-wide buffer-allocation counters (fresh, reused) across every
/// arena; benches snapshot these around a run to report allocs-per-exec.
static FRESH_ALLOCS: AtomicU64 = AtomicU64::new(0);
static REUSED_ALLOCS: AtomicU64 = AtomicU64::new(0);

/// Set the dot-general thread count for subsequent executions
/// (0 = one per available core). Plumbed from the `threads` preset knob.
pub fn set_dot_threads(n: usize) {
    DOT_THREADS.store(n, Ordering::Relaxed);
}

/// The currently requested dot-general thread count (as set, 0 = auto).
pub fn dot_threads() -> usize {
    DOT_THREADS.load(Ordering::Relaxed)
}

/// The thread count to actually use (auto resolved to the core count).
pub(crate) fn resolve_dot_threads() -> usize {
    match DOT_THREADS.load(Ordering::Relaxed) {
        0 => std::thread::available_parallelism()
            .map(|n| n.get())
            .unwrap_or(1),
        n => n,
    }
}

/// Cumulative (fresh, arena-reused) buffer allocation counts across all
/// executables in this process.
pub fn alloc_stats() -> (u64, u64) {
    (
        FRESH_ALLOCS.load(Ordering::Relaxed),
        REUSED_ALLOCS.load(Ordering::Relaxed),
    )
}

/// Reset [`alloc_stats`] to zero (bench bookkeeping).
pub fn reset_alloc_stats() {
    FRESH_ALLOCS.store(0, Ordering::Relaxed);
    REUSED_ALLOCS.store(0, Ordering::Relaxed);
}

/// A free-list of `f32` buffers scoped to one execution, seeded from (and
/// drained back into) the owning executable's pool so back-to-back
/// `execute_b` calls reuse each other's intermediates.
#[derive(Debug, Default)]
pub struct Arena {
    free: Vec<Vec<f32>>,
    fresh: u64,
    reused: u64,
}

impl Arena {
    /// Arena seeded with previously recycled buffers.
    pub fn with_free(free: Vec<Vec<f32>>) -> Arena {
        Arena {
            free,
            fresh: 0,
            reused: 0,
        }
    }

    /// A zero-filled buffer of `n` elements, recycled when possible.
    pub fn alloc(&mut self, n: usize) -> Vec<f32> {
        match self.free.pop() {
            Some(mut buf) => {
                self.reused += 1;
                buf.clear();
                buf.resize(n, 0.0);
                buf
            }
            None => {
                self.fresh += 1;
                vec![0.0f32; n]
            }
        }
    }

    /// Return a dead buffer to the free list — a no-op unless this arena
    /// holds the last reference (parameters and aliased buffers survive).
    pub fn recycle(&mut self, data: Arc<Vec<f32>>) {
        if let Ok(buf) = Arc::try_unwrap(data) {
            if buf.capacity() > 0 {
                self.free.push(buf);
            }
        }
    }

    /// Tear down into (free list, fresh count, reused count), publishing
    /// the counts to the process-wide [`alloc_stats`].
    pub fn into_parts(self) -> (Vec<Vec<f32>>, u64, u64) {
        FRESH_ALLOCS.fetch_add(self.fresh, Ordering::Relaxed);
        REUSED_ALLOCS.fetch_add(self.reused, Ordering::Relaxed);
        (self.free, self.fresh, self.reused)
    }
}

/// A strided copy `out[o] = a[walk(o)]` with the walk precomputed as an
/// odometer: per output dimension a step into the operand, plus one
/// innermost run that is contiguous (`step == 1`), a splat (`step == 0`)
/// or a fixed stride. Covers broadcast, transpose and slice.
#[derive(Debug)]
pub struct GatherPlan {
    pub(crate) base: usize,
    pub(crate) outer_sizes: Vec<usize>,
    pub(crate) outer_steps: Vec<usize>,
    pub(crate) inner_len: usize,
    pub(crate) inner_step: usize,
    pub(crate) out_len: usize,
}

impl GatherPlan {
    /// From output dims and the operand-index step of each output dim
    /// (step 0 for dims the operand does not vary along).
    pub fn new(out_dims: &[usize], steps: &[usize], base: usize) -> GatherPlan {
        let out_len: usize = out_dims.iter().product();
        // size-1 dims contribute nothing to the walk
        let mut dims: Vec<(usize, usize)> = out_dims
            .iter()
            .zip(steps)
            .map(|(&s, &p)| (s, p))
            .filter(|&(s, _)| s != 1)
            .collect();
        let (mut inner_len, mut inner_step) = (1usize, 1usize);
        if let Some((s, p)) = dims.pop() {
            inner_len = s;
            inner_step = p;
        }
        // grow the innermost run while the next dim out continues the same
        // arithmetic sequence (fills require the step to stay 0)
        while let Some(&(s, p)) = dims.last() {
            let contiguous = if inner_step == 0 {
                p == 0
            } else {
                p == inner_len * inner_step
            };
            if !contiguous {
                break;
            }
            inner_len *= s;
            dims.pop();
        }
        let (outer_sizes, outer_steps) = dims.into_iter().unzip();
        GatherPlan {
            base,
            outer_sizes,
            outer_steps,
            inner_len,
            inner_step,
            out_len,
        }
    }

    /// Number of output elements this plan produces.
    pub fn out_len(&self) -> usize {
        self.out_len
    }

    /// The largest operand offset [`run`](GatherPlan::run) can read —
    /// `base + Σ (size_i − 1)·step_i` over the outer odometer dims plus
    /// the innermost run — or `None` when the plan reads nothing at all
    /// (a zero-size output). The static verifier proves this lies inside
    /// the source buffer; merged runs and step-0 fills fall out of the
    /// same formula because merging preserves `len·step` products.
    pub fn max_reachable_offset(&self) -> Option<usize> {
        if self.out_len == 0 {
            return None;
        }
        let outer: usize = self
            .outer_sizes
            .iter()
            .zip(&self.outer_steps)
            .map(|(&s, &p)| (s - 1) * p)
            .sum();
        Some(self.base + outer + (self.inner_len - 1) * self.inner_step)
    }

    /// Execute the gather into `out` (`out.len() == self.out_len()`).
    pub fn run(&self, a: &[f32], out: &mut [f32]) {
        if self.out_len == 0 {
            return;
        }
        let nd = self.outer_sizes.len();
        let mut counters = vec![0usize; nd];
        let mut idx = self.base;
        let runs = self.out_len / self.inner_len;
        let mut o = 0usize;
        for _ in 0..runs {
            match self.inner_step {
                0 => out[o..o + self.inner_len].fill(a[idx]),
                1 => out[o..o + self.inner_len].copy_from_slice(&a[idx..idx + self.inner_len]),
                s => {
                    let mut k = idx;
                    for v in &mut out[o..o + self.inner_len] {
                        *v = a[k];
                        k += s;
                    }
                }
            }
            o += self.inner_len;
            for d in (0..nd).rev() {
                counters[d] += 1;
                idx += self.outer_steps[d];
                if counters[d] < self.outer_sizes[d] {
                    break;
                }
                counters[d] = 0;
                idx -= self.outer_sizes[d] * self.outer_steps[d];
            }
        }
    }
}

/// `iota` along one dimension: value = the middle coordinate, layout
/// `prefix × size × suffix`.
pub fn iota_fill(out: &mut [f32], size: usize, suffix: usize) {
    if suffix == 0 || size == 0 {
        return;
    }
    let period = size * suffix;
    let mut o = 0usize;
    while o < out.len() {
        for v in 0..size {
            out[o..o + suffix].fill(v as f32);
            o += suffix;
        }
        debug_assert!(o % period == 0);
    }
}

/// Dot-general lowered to offset tables over flat storage, plus the block
/// sizes the executor tiles with. Built once per instruction at plan time.
#[derive(Debug)]
pub struct DotPlan {
    /// Batch offset tables (lhs / rhs), walked in lockstep.
    pub bl: Vec<usize>,
    pub br: Vec<usize>,
    /// Contraction offset tables (lhs / rhs), walked in lockstep — this
    /// order IS the accumulation order and must match the reference
    /// evaluator exactly.
    pub cl: Vec<usize>,
    pub cr: Vec<usize>,
    /// Free-dimension offset tables (lhs rows / rhs columns).
    pub lf: Vec<usize>,
    pub rf: Vec<usize>,
    /// Whether the rhs free offsets are 0,1,2,… (trailing free dims).
    pub rf_contiguous: bool,
    /// Total output elements (`bl.len() * lf.len() * rf.len()`).
    pub out_len: usize,
    /// 2·b·m·n·k — used to size the thread pool to the work.
    pub flops: usize,
}

/// Split `0..rows` into one contiguous chunk per engaged thread.
///
/// This is the *single* definition of the dot-general work partition: the
/// executor spawns one scoped thread per returned `(start, end)` range,
/// and the static verifier ([`crate::verify`]) re-checks that the ranges
/// tile the row space exactly — every row covered once, no overlap, no
/// gap — at every thread count, which is the precondition for the
/// bit-identical `--threads` determinism contract.
pub(crate) fn partition_rows(rows: usize, threads: usize) -> Vec<(usize, usize)> {
    let per = rows.div_ceil(threads.max(1)).max(1);
    let mut parts = Vec::new();
    let mut start = 0usize;
    while start < rows {
        let end = (start + per).min(rows);
        parts.push((start, end));
        start = end;
    }
    parts
}

/// lhs rows sharing one rhs element load in the blocked microkernel.
const ROW_TILE: usize = 4;
/// Accumulator/rhs row segment length per pass (f32s; 2 KiB ≪ L1).
const COL_BLOCK: usize = 512;
/// Don't engage an extra thread below this many flops of work for it.
const MIN_FLOPS_PER_THREAD: usize = 1 << 18;

impl DotPlan {
    /// Execute into a zero-initialised `out` of `self.out_len` elements.
    ///
    /// Determinism contract: each output element is owned by exactly one
    /// thread and accumulated serially over the contraction table in
    /// order, skipping lhs terms that are exactly `0.0` — the same order
    /// and the same skips as the reference evaluator, at every `threads`.
    pub fn execute(&self, a: &[f32], b: &[f32], out: &mut [f32], threads: usize) {
        let nrf = self.rf.len();
        let nlf = self.lf.len();
        let rows = self.bl.len() * nlf;
        if rows == 0 || nrf == 0 {
            return;
        }
        let threads = self.effective_threads(threads, rows);
        if threads <= 1 {
            self.run_rows(a, b, out, 0, rows);
            return;
        }
        std::thread::scope(|scope| {
            let mut rest = out;
            for (start, end) in partition_rows(rows, threads) {
                let (chunk, tail) = rest.split_at_mut((end - start) * nrf);
                rest = tail;
                scope.spawn(move || self.run_rows(a, b, chunk, start, end));
            }
        });
    }

    /// Threads actually engaged for `requested` over `rows` output rows
    /// (crate-visible so the static verifier checks the partition at the
    /// thread counts execution would really use).
    pub(crate) fn effective_threads(&self, requested: usize, rows: usize) -> usize {
        if requested <= 1 || rows <= 1 {
            return 1;
        }
        let by_work = (self.flops / MIN_FLOPS_PER_THREAD).max(1);
        requested.min(rows).min(by_work)
    }

    /// Global output rows `g0..g1`; `out` holds exactly those rows.
    fn run_rows(&self, a: &[f32], b: &[f32], out: &mut [f32], g0: usize, g1: usize) {
        let nlf = self.lf.len();
        let nrf = self.rf.len();
        let mut g = g0;
        while g < g1 {
            let bi = g / nlf;
            let li = g - bi * nlf;
            let run = ((bi + 1) * nlf).min(g1) - g;
            let base = (g - g0) * nrf;
            self.run_batch_rows(a, b, &mut out[base..base + run * nrf], bi, li, run);
            g += run;
        }
    }

    /// `run` consecutive lhs-free rows of batch `bi`, starting at `li0`.
    fn run_batch_rows(
        &self,
        a: &[f32],
        b: &[f32],
        out: &mut [f32],
        bi: usize,
        li0: usize,
        run: usize,
    ) {
        let nrf = self.rf.len();
        let bl_off = self.bl[bi];
        let br_off = self.br[bi];
        if !self.rf_contiguous {
            // rare layout (rhs free dims not trailing): plain rows, still
            // in reference accumulation order
            for t in 0..run {
                let row = &mut out[t * nrf..(t + 1) * nrf];
                let lbase = bl_off + self.lf[li0 + t];
                for (&cl_off, &cr_off) in self.cl.iter().zip(&self.cr) {
                    let x = a[lbase + cl_off];
                    if x == 0.0 {
                        continue;
                    }
                    let rbase = br_off + cr_off;
                    for (acc, &roff) in row.iter_mut().zip(&self.rf) {
                        *acc += x * b[rbase + roff];
                    }
                }
            }
            return;
        }
        // blocked microkernel: tiles of ROW_TILE accumulator rows share
        // each rhs row segment (still hot in L1 across the tile), and the
        // inner j-loop over a COL_BLOCK segment autovectorises
        let mut t0 = 0usize;
        while t0 < run {
            let tl = ROW_TILE.min(run - t0);
            let tile = &mut out[t0 * nrf..(t0 + tl) * nrf];
            let mut j0 = 0usize;
            while j0 < nrf {
                let j1 = (j0 + COL_BLOCK).min(nrf);
                for (&cl_off, &cr_off) in self.cl.iter().zip(&self.cr) {
                    let rrow = &b[br_off + cr_off + j0..br_off + cr_off + j1];
                    for t in 0..tl {
                        let x = a[bl_off + self.lf[li0 + t0 + t] + cl_off];
                        if x == 0.0 {
                            continue;
                        }
                        let acc = &mut tile[t * nrf + j0..t * nrf + j1];
                        for (o, &y) in acc.iter_mut().zip(rrow) {
                            *o += x * y;
                        }
                    }
                }
                j0 = j1;
            }
            t0 += tl;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn arena_reuses_unshared_buffers() {
        let mut arena = Arena::default();
        let a = arena.alloc(16);
        assert_eq!(a.len(), 16);
        arena.recycle(Arc::new(a));
        let b = arena.alloc(4);
        assert!(b.iter().all(|&v| v == 0.0));
        let shared = Arc::new(vec![1.0f32; 8]);
        let keep = Arc::clone(&shared);
        arena.recycle(shared); // refcount 2: must NOT enter the free list
        let (free, fresh, reused) = arena.into_parts();
        assert_eq!(free.len(), 0, "shared buffer was not recycled");
        assert_eq!((fresh, reused), (1, 1));
        assert_eq!(keep.len(), 8);
    }

    #[test]
    fn gather_merges_contiguous_runs() {
        // transpose-free identity: one big run
        let plan = GatherPlan::new(&[2, 3], &[3, 1], 0);
        let a = [0.0, 1.0, 2.0, 3.0, 4.0, 5.0];
        let mut out = [0.0f32; 6];
        plan.run(&a, &mut out);
        assert_eq!(out, a);
        // transpose [2,3] -> [3,2]
        let plan = GatherPlan::new(&[3, 2], &[1, 3], 0);
        let mut out = [0.0f32; 6];
        plan.run(&a, &mut out);
        assert_eq!(out, [0.0, 3.0, 1.0, 4.0, 2.0, 5.0]);
        // broadcast a scalar-ish run: step-0 inner
        let plan = GatherPlan::new(&[2, 2], &[1, 0], 0);
        let mut out = [0.0f32; 4];
        plan.run(&[7.0, 9.0], &mut out);
        assert_eq!(out, [7.0, 7.0, 9.0, 9.0]);
    }

    #[test]
    fn partition_rows_tiles_exactly_at_every_thread_count() {
        for rows in [1usize, 2, 3, 7, 64, 1000] {
            for threads in 1..=12 {
                let parts = partition_rows(rows, threads);
                assert!(parts.len() <= threads.max(1));
                let mut next = 0usize;
                for &(start, end) in &parts {
                    assert_eq!(start, next, "gap or overlap at {rows}x{threads}");
                    assert!(end > start, "empty chunk at {rows}x{threads}");
                    next = end;
                }
                assert_eq!(next, rows, "rows uncovered at {rows}x{threads}");
            }
        }
        assert!(partition_rows(0, 4).is_empty());
    }

    #[test]
    fn gather_max_offset_covers_merged_and_zero_size_plans() {
        // transpose [2,3] -> [3,2]: last read is element 5
        let plan = GatherPlan::new(&[3, 2], &[1, 3], 0);
        assert_eq!(plan.max_reachable_offset(), Some(5));
        // merged contiguous identity: one run of 6 from base 0
        let plan = GatherPlan::new(&[2, 3], &[3, 1], 0);
        assert_eq!(plan.max_reachable_offset(), Some(5));
        // step-0 fill never moves past its base
        let plan = GatherPlan::new(&[2, 2], &[1, 0], 0);
        assert_eq!(plan.max_reachable_offset(), Some(1));
        // zero-size output reads nothing at all
        let plan = GatherPlan::new(&[0, 3], &[3, 1], 0);
        assert_eq!(plan.max_reachable_offset(), None);
    }

    #[test]
    fn iota_fill_matches_definition() {
        let mut out = [0.0f32; 12]; // dims [2,3,2], iota dim 1
        iota_fill(&mut out, 3, 2);
        assert_eq!(out, [0.0, 0.0, 1.0, 1.0, 2.0, 2.0, 0.0, 0.0, 1.0, 1.0, 2.0, 2.0]);
    }
}
