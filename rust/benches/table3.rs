//! Bench: regenerate Table 3 (post-synthesis resources/latency).
//!
//! Times the HLS synthesis simulator itself (it must price thousands of
//! candidates per search), then runs the local-search → synthesis flow on
//! the three Table 3 architectures at bench scale and prints the rows.

mod common;

use snac_pack::compress::{local_search, synthesis_nnz, LocalSearchConfig};
use snac_pack::data::Dataset;
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::{Activation, Genome, SearchSpace, SupernetInputs};
use snac_pack::report::{render_table3, Table3Row};
use snac_pack::runtime::Runtime;
use snac_pack::trainer::Trainer;
use snac_pack::util::Rng;

fn main() -> anyhow::Result<()> {
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    let hls = HlsConfig::default();

    // --- simulator micro-bench: it sits inside the surrogate's label
    //     generator AND prices every Table 3 row ---
    let mut rng = Rng::new(0);
    let genomes: Vec<Genome> = (0..256).map(|_| space.sample(&mut rng)).collect();
    let mean = common::bench("table3/synthesize_256_networks", 3, 20, || {
        genomes
            .iter()
            .map(|g| synthesize(&NetworkSpec::from_genome(g, &space, 8, 0.5), &hls, &device).lut)
            .sum::<u64>()
    });
    println!(
        "  simulator throughput: {}",
        common::per_sec(256, mean)
    );

    // --- the Table 3 flow at bench scale ---
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    let ds = Dataset::generate(1280, 384, 384, 7);
    let trainer = Trainer::new(&rt, &ds);
    let cfg = LocalSearchConfig {
        warmup_epochs: 1,
        imp_iterations: 4,
        epochs_per_iteration: 1,
        ..Default::default()
    };
    // baseline + two representative search winners (NAC-thin ReLU,
    // SNAC-like tanh) — the full pipeline picks these dynamically; the
    // bench pins them for stable timing.
    let nac_like = Genome {
        n_layers: 4,
        width_idx: [0, 0, 0, 0, 0, 0, 0, 0],
        act: Activation::Tanh,
        batch_norm: false,
        lr_idx: 2,
        l1_idx: 0,
        dropout_idx: 0,
    };
    let snac_like = Genome {
        n_layers: 4,
        width_idx: [0, 0, 0, 0, 0, 0, 0, 0],
        act: Activation::ReLU,
        batch_norm: false,
        lr_idx: 2,
        l1_idx: 0,
        dropout_idx: 0,
    };
    let mut rows = Vec::new();
    for (name, genome, softmax) in [
        ("Baseline [12]", space.baseline(), true),
        ("Optimal NAC (repr.)", nac_like, false),
        ("Optimal SNAC-Pack (repr.)", snac_like, false),
    ] {
        let t0 = std::time::Instant::now();
        let mut rng = Rng::new(13);
        let result = local_search(&trainer, &genome, &space, &cfg, &mut rng)?;
        let inputs = SupernetInputs::compile(&genome, &space);
        let nnz = synthesis_nnz(
            &result.model.params,
            &result.masks,
            &inputs,
            &genome,
            &space,
            cfg.bits,
        );
        let mut spec = NetworkSpec::from_genome_with_nnz(&genome, &space, cfg.bits, &nnz);
        spec.softmax_head = softmax;
        let report = synthesize(&spec, &hls, &device);
        println!(
            "bench table3/local+synth {name:<26} {:>10}",
            common::fmt(t0.elapsed().as_secs_f64())
        );
        rows.push(Table3Row {
            model: name.to_string(),
            report,
        });
    }
    println!("\n{}", render_table3(&rows, &device));
    Ok(())
}
