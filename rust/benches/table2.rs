//! Bench: regenerate Table 2 (global-search comparison) at bench scale.
//!
//! Runs the three-way comparison — baseline, NAC objectives, SNAC-Pack
//! objectives — on a scaled-down budget and prints the Table 2 rows plus
//! the wall-clock cost of each search. `--full` (or BENCH_PRESET=ci/paper)
//! scales up.

mod common;

use snac_pack::config::Preset;
use snac_pack::coordinator::run_pipeline;
use snac_pack::runtime::Runtime;

fn main() -> anyhow::Result<()> {
    let preset_name =
        std::env::var("BENCH_PRESET").unwrap_or_else(|_| "quickstart".to_string());
    let preset = Preset::by_name(&preset_name)?;
    println!(
        "== Table 2 bench (preset `{}`: {} trials × {} epochs) ==",
        preset.name, preset.search.trials, preset.search.epochs
    );
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    let t0 = std::time::Instant::now();
    let summary = run_pipeline(&rt, &preset, std::path::Path::new("results/bench_table2"))?;
    println!("{}", summary.table2);
    for (stage, secs) in &summary.timings {
        println!("bench table2/{stage:<30} {:>10}", common::fmt(*secs));
    }
    println!(
        "bench table2/TOTAL {:>45}",
        common::fmt(t0.elapsed().as_secs_f64())
    );
    Ok(())
}
