//! Bench: regenerate Figures 1–4 (Pareto scatter plots).
//!
//! Reuses the trial databases produced by the pipeline when present
//! (`results/trials_{nac,snac}.json`); otherwise runs a miniature pair of
//! searches first. Times the figure/report generation itself as well.

mod common;

use snac_pack::config::Preset;
use snac_pack::coordinator::{global_search, GlobalSearchConfig, TrialRecord};
use snac_pack::data::Dataset;
use snac_pack::hls::{FpgaDevice, HlsConfig};
use snac_pack::nn::SearchSpace;
use snac_pack::objectives::{ObjectiveContext, ObjectiveKind};
use snac_pack::report::write_figures;
use snac_pack::runtime::Runtime;
use snac_pack::surrogate::{train_surrogate, SurrogatePredictor};

fn main() -> anyhow::Result<()> {
    let space = SearchSpace::table1();
    let results = std::path::Path::new("results");
    let (snac_records, nac_records) = if results.join("trials_snac.json").exists() {
        println!("== figures bench: reusing results/trials_*.json ==");
        (
            TrialRecord::load_all(&results.join("trials_snac.json"), &space)?,
            TrialRecord::load_all(&results.join("trials_nac.json"), &space)?,
        )
    } else {
        println!("== figures bench: no saved trials; running mini searches ==");
        let preset = Preset::by_name("quickstart")?;
        let rt = Runtime::load(std::path::Path::new("artifacts"))?;
        let ds = Dataset::generate(
            preset.data.n_train,
            preset.data.n_val,
            preset.data.n_test,
            preset.data.seed,
        );
        let device = FpgaDevice::vu13p();
        let (sp, _) = train_surrogate(
            &rt,
            &space,
            &preset.surrogate,
            &HlsConfig::default(),
            &device,
        )?;
        let surrogate = SurrogatePredictor::new(&rt, sp);
        let mut run = |objs: Vec<ObjectiveKind>, use_sur: bool| -> anyhow::Result<Vec<TrialRecord>> {
            Ok(global_search(
                &rt,
                &ds,
                &space,
                GlobalSearchConfig {
                    objectives: objs,
                    ctx: ObjectiveContext {
                        space: &space,
                        device: &device,
                        surrogate: use_sur.then_some(&surrogate),
                        bits: 8,
                        sparsity: 0.5,
                    },
                    nsga2: preset.nsga2(),
                    trials: preset.search.trials,
                    epochs: preset.search.epochs,
                    seed: preset.seed,
                    workers: preset.search.workers,
                    accuracy_threshold: 0.0,
                    progress: None,
                    cache_path: None,
                    checkpoint: None,
                },
            )?
            .records)
        };
        let nac = run(ObjectiveKind::nac_set(), false)?;
        let snac = run(ObjectiveKind::snac_set(), true)?;
        (snac, nac)
    };

    println!(
        "trial clouds: SNAC {} points, NAC {} points",
        snac_records.len(),
        nac_records.len()
    );
    let out = std::path::Path::new("results/bench_figures");
    let mut rendered = String::new();
    common::bench("figures/write_fig1-4", 2, 20, || {
        rendered = write_figures(&snac_records, &nac_records, out).unwrap();
    });
    println!("{rendered}");
    Ok(())
}
