//! Bench: global-search trial throughput vs evaluation worker count.
//!
//! Drives the real search machinery — NSGA-II, the streaming evaluation
//! pool, the genome-keyed evaluation cache — through `global_search_with`
//! with a simulated trial evaluator whose cost is CPU-bound work in the
//! HLS synthesis simulator (no runtime artifacts required, so this runs
//! anywhere and stays comparable across PRs). Three phases:
//!
//! 1. **Worker scaling** — trials/sec at `workers ∈ {1, 2, 4}`, verifying
//!    the identical trial stream for every worker count; then the same
//!    4-worker budget with the tracer live (`telemetry::init`), asserting
//!    an identical trial stream and recording the tracing overhead.
//! 2. **Streaming vs chunked dispatch** — under heavy per-trial cost
//!    skew, compares the streaming completion queue against the old
//!    chunked-barrier dispatch (reproduced here), asserting the stream
//!    produces identical results at no higher wall-clock cost.
//! 3. **Cache persistence** — runs the same search twice against one
//!    `EvalCache` snapshot file and asserts the warm run retrains
//!    nothing.
//! 4. **Interpreter execute throughput** — loads the PJRT runtime against
//!    the checked-in HLO fixtures (or real AOT artifacts when built) and
//!    times `surrogate_predict`/`train_step` executions through the
//!    `rust/xla` HLO interpreter: the compiled execution plans vs the
//!    retained naive reference evaluator (speedup measured in-run), plus
//!    the blocked dot-general kernel's GFLOP/s and the buffer arena's
//!    allocations-per-execution.
//! 5. **Sharded dispatch** — the same search through the multi-process
//!    shard protocol (file-based queue + lease claims, worker loops on
//!    threads), verifying the trial stream stays identical and recording
//!    the protocol's throughput next to the in-process numbers; then the
//!    identical budget over the TCP transport (in-process task server,
//!    workers claiming over loopback HTTP), recording the fs-vs-tcp
//!    throughput side by side.
//! 6. **Surrogate batching + serving** — rows/sec of the per-trial
//!    (one padded execution per genome) vs generation-batched
//!    (⌈N/`SUR_BATCH`⌉ executions) surrogate paths, then `serve_load`:
//!    sustained req/s and p50/p99 latency of the `snac-pack serve`
//!    front under concurrent clients, measured for one-shot
//!    (connection-per-request) and keep-alive (persistent `HttpClient`)
//!    clients over a memo-warm engine — the delta is pure transport
//!    cost, so keep-alive must be strictly faster — with every served
//!    estimate asserted bit-identical to the in-process predictor.
//!
//! Writes `BENCH_search.json` for the per-commit perf trajectory.

mod common;

use std::path::Path;
use std::sync::Arc;
use std::time::{Duration, Instant};

use snac_pack::coordinator::{global_search_with, SearchLoopConfig, SearchOutcome};
use snac_pack::eval::{
    run_worker_on, EvalCache, EvalRequest, FsTransport, ParallelEvaluator, ShardDriver,
    ShardTimings, ShardTransport, StageSpec, TcpHost, TcpWorker, TrialEvaluation, TrialEvaluator,
    WorkerOptions,
};
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::{self, Genome, SearchSpace};
use snac_pack::objectives::ObjectiveKind;
use snac_pack::runtime::runtime::arg;
use snac_pack::runtime::Runtime;
use snac_pack::search::Nsga2Config;
use snac_pack::serve::{http, EngineConfig, ServeContext, ServeMetrics, ServeTuning, SurrogateEngine};
use snac_pack::surrogate::{genome_features, SurrogateParams, SurrogatePredictor};
use snac_pack::telemetry;
use snac_pack::util::stats::sorted_quantile;
use snac_pack::util::{Json, Rng};

const TRIALS: usize = 48;
const POPULATION: usize = 8;
const SEED: u64 = 17;
/// Simulator passes per trial — sized so one trial costs milliseconds,
/// like a (very) small training run, dwarfing scheduling overhead.
const SIM_PASSES: usize = 300;
/// Trial count / worker count for the dispatch-strategy comparison.
const SKEW_TRIALS: usize = 48;
const SKEW_WORKERS: usize = 4;

/// Stand-in for the train-and-score path: deterministic accuracy with a
/// real size/accuracy trade-off, priced by a CPU-bound simulator loop.
struct SimulatedTrainer {
    space: SearchSpace,
    hls: HlsConfig,
    device: FpgaDevice,
}

fn simulated_trainer() -> SimulatedTrainer {
    SimulatedTrainer {
        space: SearchSpace::table1(),
        hls: HlsConfig::default(),
        device: FpgaDevice::vu13p(),
    }
}

fn score(genome: &Genome, space: &SearchSpace, rng: &mut Rng, t0: Instant) -> TrialEvaluation {
    let weights = genome.num_weights(space) as f64;
    let accuracy = (1.0 - (-weights / 4000.0).exp()) * (0.9 + 0.1 * rng.uniform());
    TrialEvaluation {
        accuracy,
        bops: weights,
        est_avg_resources: None,
        est_clock_cycles: None,
        objectives: vec![-accuracy, weights],
        train_seconds: t0.elapsed().as_secs_f64(),
    }
}

impl TrialEvaluator for SimulatedTrainer {
    fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> anyhow::Result<TrialEvaluation> {
        let t0 = Instant::now();
        let mut lut_sum = 0u64;
        for pass in 0..SIM_PASSES {
            let sparsity = (pass % 8) as f64 / 16.0;
            let spec = NetworkSpec::from_genome(genome, &self.space, 8, sparsity);
            lut_sum += std::hint::black_box(synthesize(&spec, &self.hls, &self.device)).lut;
        }
        std::hint::black_box(lut_sum);
        Ok(score(genome, &self.space, rng, t0))
    }
}

/// Same workload with a deterministic per-genome cost skew (~16x between
/// the cheapest and dearest trial): exactly the regime where a chunked
/// dispatch idles workers at every chunk barrier.
struct SkewedTrainer {
    space: SearchSpace,
    hls: HlsConfig,
    device: FpgaDevice,
}

fn skewed_trainer() -> SkewedTrainer {
    SkewedTrainer {
        space: SearchSpace::table1(),
        hls: HlsConfig::default(),
        device: FpgaDevice::vu13p(),
    }
}

impl TrialEvaluator for SkewedTrainer {
    fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> anyhow::Result<TrialEvaluation> {
        let t0 = Instant::now();
        let weights = genome.num_weights(&self.space);
        let passes = 40 + weights.wrapping_mul(7919) % 600;
        let mut lut_sum = 0u64;
        for pass in 0..passes {
            let sparsity = (pass % 8) as f64 / 16.0;
            let spec = NetworkSpec::from_genome(genome, &self.space, 8, sparsity);
            lut_sum += std::hint::black_box(synthesize(&spec, &self.hls, &self.device)).lut;
        }
        std::hint::black_box(lut_sum);
        Ok(score(genome, &self.space, rng, t0))
    }
}

fn run(workers: usize) -> (SearchOutcome, f64) {
    run_with_cache(workers, EvalCache::in_memory())
}

fn run_with_cache(workers: usize, cache: EvalCache) -> (SearchOutcome, f64) {
    let space = SearchSpace::table1();
    let pool = ParallelEvaluator::with_cache(simulated_trainer(), workers, cache);
    let t0 = Instant::now();
    let outcome = global_search_with(
        &pool,
        &space,
        SearchLoopConfig {
            nsga2: Nsga2Config {
                population: POPULATION,
                ..Default::default()
            },
            trials: TRIALS,
            seed: SEED,
            accuracy_threshold: 0.0,
            progress: None,
            checkpoint: None,
        },
    )
    .expect("simulated search");
    (outcome, t0.elapsed().as_secs_f64())
}

fn requests(genomes: &[Genome], seed: u64) -> Vec<EvalRequest> {
    let mut root = Rng::new(seed);
    genomes
        .iter()
        .enumerate()
        .map(|(trial_id, genome)| EvalRequest {
            trial_id,
            genome: genome.clone(),
            rng: root.fork(trial_id as u64),
        })
        .collect()
}

fn distinct_genomes(n: usize, seed: u64) -> Vec<Genome> {
    let space = SearchSpace::table1();
    let mut rng = Rng::new(seed);
    let mut out: Vec<Genome> = Vec::new();
    while out.len() < n {
        let g = space.sample(&mut rng);
        if !out.contains(&g) {
            out.push(g);
        }
    }
    out
}

/// The old (pre-streaming) driver: worker-sized chunks with a barrier at
/// every chunk boundary. Kept here as the reference the streaming path
/// must beat (or at worst match).
fn dispatch_chunked(pool: &ParallelEvaluator<SkewedTrainer>, reqs: Vec<EvalRequest>) -> Vec<f64> {
    let chunk_size = pool.workers().max(1);
    let mut accs = Vec::with_capacity(reqs.len());
    let mut queued = reqs.into_iter();
    loop {
        let chunk: Vec<EvalRequest> = queued.by_ref().take(chunk_size).collect();
        if chunk.is_empty() {
            break;
        }
        for trial in pool.evaluate_batch(chunk).expect("chunked dispatch") {
            accs.push(trial.evaluation.accuracy);
        }
    }
    accs
}

fn dispatch_streaming(pool: &ParallelEvaluator<SkewedTrainer>, reqs: Vec<EvalRequest>) -> Vec<f64> {
    let mut accs = Vec::with_capacity(reqs.len());
    pool.evaluate_stream(reqs, |trial| accs.push(trial.evaluation.accuracy))
        .expect("streaming dispatch");
    accs
}

/// The dispatch medium for [`run_sharded`]: the rename-based file
/// protocol over a run directory, or HTTP to an in-process task server
/// over loopback. The protocol core and the driver merge are identical
/// either way, so the trial stream must be too.
enum Transport {
    Fs,
    Tcp,
}

/// Phase 5: the identical search budget dispatched through the shard
/// protocol — driver partitions each generation into `shards` tasks,
/// `workers` worker loops (threads here; separate processes in
/// production) claim and evaluate them with the same simulated trainer.
fn run_sharded(transport: Transport, shards: usize, workers: usize) -> (SearchOutcome, f64) {
    let space = SearchSpace::table1();
    let run_dir = std::env::temp_dir().join(format!(
        "snac_bench_shard_{}_{shards}_{workers}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&run_dir);
    let (driver_t, worker_ts): (Arc<dyn ShardTransport>, Vec<Arc<dyn ShardTransport>>) =
        match transport {
            Transport::Fs => {
                let mk = || -> Arc<dyn ShardTransport> {
                    Arc::new(FsTransport::new(&run_dir).expect("fs transport"))
                };
                (mk(), (0..workers).map(|_| mk()).collect())
            }
            Transport::Tcp => {
                let host = Arc::new(
                    TcpHost::listen("127.0.0.1:0", None, "bench-tok").expect("tcp task server"),
                );
                let addr = host.addr().to_string();
                let ws = (0..workers)
                    .map(|_| {
                        Arc::new(TcpWorker::connect(&addr, Duration::from_secs(5), "bench-tok"))
                            as Arc<dyn ShardTransport>
                    })
                    .collect();
                (host as Arc<dyn ShardTransport>, ws)
            }
        };
    let driver = ShardDriver::with_transport(
        Arc::clone(&driver_t),
        "bench",
        StageSpec {
            objectives: ObjectiveKind::nac_set(),
            epochs: 1,
        },
        shards,
        EvalCache::in_memory(),
        ShardTimings {
            poll: Duration::from_millis(2),
            ..Default::default()
        },
    )
    .expect("shard driver");
    let opts = WorkerOptions {
        poll: Duration::from_millis(2),
        heartbeat: Duration::from_millis(500),
        ..Default::default()
    };
    // always request shutdown — even when the driver panics — so worker
    // threads exit and the scope can join instead of hanging the bench
    struct ShutdownOnDrop(Arc<dyn ShardTransport>);
    impl Drop for ShutdownOnDrop {
        fn drop(&mut self) {
            let _ = self.0.request_shutdown();
        }
    }
    let t0 = Instant::now();
    let outcome = std::thread::scope(|s| {
        let _guard = ShutdownOnDrop(Arc::clone(&driver_t));
        for wt in worker_ts {
            let opts = opts.clone();
            s.spawn(move || {
                let trainer = simulated_trainer();
                run_worker_on(wt, &opts, |_stage, reqs| {
                    reqs.iter()
                        .map(|req| {
                            let mut rng = req.rng.clone();
                            trainer.evaluate(&req.genome, &mut rng)
                        })
                        .collect()
                })
                .expect("bench worker");
            });
        }
        global_search_with(
            &driver,
            &space,
            SearchLoopConfig {
                nsga2: Nsga2Config {
                    population: POPULATION,
                    ..Default::default()
                },
                trials: TRIALS,
                seed: SEED,
                accuracy_threshold: 0.0,
                progress: None,
                checkpoint: None,
            },
        )
        .expect("sharded search")
    });
    let secs = t0.elapsed().as_secs_f64();
    let _ = std::fs::remove_dir_all(&run_dir);
    (outcome, secs)
}

/// Phase 4: time HLO executions through the `rust/xla` interpreter (or
/// real PJRT bindings when the native artifacts are built). Returns the
/// JSON block for `BENCH_search.json`.
fn bench_interpreter() -> anyhow::Result<Json> {
    let dir = snac_pack::runtime::artifact_dir()
        .ok_or_else(|| anyhow::anyhow!("no artifact/fixture manifest in this tree"))?;
    let t0 = Instant::now();
    let rt = Runtime::load(&dir)?;
    let load_secs = t0.elapsed().as_secs_f64();
    let mut rng = Rng::new(99);

    // surrogate_predict: the per-generation estimate batch
    let mut sw1 = vec![0.0f32; nn::SUR_FEATS * nn::SUR_HIDDEN];
    let mut sw2 = vec![0.0f32; nn::SUR_HIDDEN * nn::SUR_HIDDEN];
    let mut sw3 = vec![0.0f32; nn::SUR_HIDDEN * nn::SUR_OUT];
    rng.fill_normal(&mut sw1, 0.1);
    rng.fill_normal(&mut sw2, 0.1);
    rng.fill_normal(&mut sw3, 0.1);
    let sb1 = vec![0.0f32; nn::SUR_HIDDEN];
    let sb2 = vec![0.0f32; nn::SUR_HIDDEN];
    let sb3 = vec![0.0f32; nn::SUR_OUT];
    let mut x = vec![0.0f32; nn::SUR_BATCH * nn::SUR_FEATS];
    rng.fill_normal(&mut x, 1.0);
    let predict_args = [
        arg("sw1", &sw1),
        arg("sb1", &sb1),
        arg("sw2", &sw2),
        arg("sb2", &sb2),
        arg("sw3", &sw3),
        arg("sb3", &sb3),
        arg("x", &x),
    ];
    const PREDICT_EXECS: usize = 32;
    rt.run("surrogate_predict", &predict_args)?; // warm-up
    let t0 = Instant::now();
    for _ in 0..PREDICT_EXECS {
        std::hint::black_box(rt.run("surrogate_predict", &predict_args)?);
    }
    let predict_secs = t0.elapsed().as_secs_f64();

    // train_step: the trial-training hot path
    let space = SearchSpace::table1();
    let genome = space.baseline();
    let inputs = snac_pack::nn::SupernetInputs::compile(&genome, &space);
    let masks = snac_pack::nn::PruneMasks::ones();
    let params = snac_pack::nn::SupernetParams::init(&mut rng);
    let adam = snac_pack::nn::SupernetParams::zeros();
    let mut hp = [0.0f32; nn::HP_LEN];
    hp[nn::HP_BN_GATE] = inputs.bn_gate;
    hp[nn::HP_LR] = inputs.lr;
    hp[nn::HP_BITS] = 8.0;
    hp[nn::HP_BETA1] = 0.9;
    hp[nn::HP_BETA2] = 0.999;
    hp[nn::HP_EPS] = 1e-8;
    hp[nn::HP_BETA1_POW] = 0.9;
    hp[nn::HP_BETA2_POW] = 0.999;
    hp[nn::HP_BN_MOM] = 0.1;
    let run_mean = vec![0.0f32; nn::NUM_LAYERS * nn::PAD];
    let run_var = vec![1.0f32; nn::NUM_LAYERS * nn::PAD];
    let mut xb = vec![0.0f32; nn::BATCH * nn::IN_DIM];
    rng.fill_normal(&mut xb, 1.0);
    let mut y1h = vec![0.0f32; nn::BATCH * nn::OUT_DIM];
    for r in 0..nn::BATCH {
        y1h[r * nn::OUT_DIM + r % nn::OUT_DIM] = 1.0;
    }
    let train_args = [
        arg("w0", &params.w0),
        arg("wh", &params.wh),
        arg("b", &params.b),
        arg("gamma", &params.gamma),
        arg("beta", &params.beta),
        arg("wo", &params.wo),
        arg("bo", &params.bo),
        arg("m_w0", &adam.w0),
        arg("m_wh", &adam.wh),
        arg("m_b", &adam.b),
        arg("m_gamma", &adam.gamma),
        arg("m_beta", &adam.beta),
        arg("m_wo", &adam.wo),
        arg("m_bo", &adam.bo),
        arg("v_w0", &adam.w0),
        arg("v_wh", &adam.wh),
        arg("v_b", &adam.b),
        arg("v_gamma", &adam.gamma),
        arg("v_beta", &adam.beta),
        arg("v_wo", &adam.wo),
        arg("v_bo", &adam.bo),
        arg("unit", &inputs.unit),
        arg("p0", &masks.p0),
        arg("ph", &masks.ph),
        arg("po", &masks.po),
        arg("gates", &inputs.gates),
        arg("act_sel", &inputs.act_sel),
        arg("hp", &hp),
        arg("run_mean", &run_mean),
        arg("run_var", &run_var),
        arg("x", &xb),
        arg("y1h", &y1h),
    ];
    const TRAIN_EXECS: usize = 32;
    rt.run("train_step", &train_args)?; // warm-up
    xla::reset_alloc_stats();
    let t0 = Instant::now();
    for _ in 0..TRAIN_EXECS {
        std::hint::black_box(rt.run("train_step", &train_args)?);
    }
    let train_secs = t0.elapsed().as_secs_f64();
    let (fresh, reused) = xla::alloc_stats();
    let fresh_per_exec = fresh as f64 / TRAIN_EXECS as f64;
    let reused_per_exec = reused as f64 / TRAIN_EXECS as f64;

    // the retained naive evaluator on the same executables: the planned
    // path's speedup is measured inside one run, so the comparison never
    // depends on a checkout of the pre-plan revision
    const REF_EXECS: usize = 4;
    xla::set_reference_mode(true);
    let ref_result = (|| -> anyhow::Result<(f64, f64)> {
        rt.run("surrogate_predict", &predict_args)?; // warm-up
        let t0 = Instant::now();
        for _ in 0..REF_EXECS {
            std::hint::black_box(rt.run("surrogate_predict", &predict_args)?);
        }
        let ref_predict_secs = t0.elapsed().as_secs_f64();
        rt.run("train_step", &train_args)?; // warm-up
        let t0 = Instant::now();
        for _ in 0..REF_EXECS {
            std::hint::black_box(rt.run("train_step", &train_args)?);
        }
        Ok((ref_predict_secs, t0.elapsed().as_secs_f64()))
    })();
    xla::set_reference_mode(false);
    let (ref_predict_secs, ref_train_secs) = ref_result?;
    let predict_eps = PREDICT_EXECS as f64 / predict_secs;
    let train_eps = TRAIN_EXECS as f64 / train_secs;
    let ref_predict_eps = REF_EXECS as f64 / ref_predict_secs;
    let ref_train_eps = REF_EXECS as f64 / ref_train_secs;

    // blocked dot-general in isolation: a square f32 matmul big enough
    // that kernel time dwarfs dispatch
    const DOT_N: usize = 256;
    const DOT_EXECS: usize = 8;
    let dot_text = format!(
        "HloModule bench_dot\n\nENTRY %main (a: f32[{n},{n}], b: f32[{n},{n}]) \
         -> f32[{n},{n}] {{\n  %a = f32[{n},{n}] parameter(0)\n  \
         %b = f32[{n},{n}] parameter(1)\n  \
         ROOT %d = f32[{n},{n}] dot(%a, %b), lhs_contracting_dims={{1}}, \
         rhs_contracting_dims={{0}}\n}}\n",
        n = DOT_N
    );
    let client = xla::PjRtClient::cpu()?;
    let dot_exe = client.compile(&xla::XlaComputation::from_proto(
        &xla::HloModuleProto::from_text(&dot_text)?,
    ))?;
    let mut da = vec![0.0f32; DOT_N * DOT_N];
    let mut db = vec![0.0f32; DOT_N * DOT_N];
    rng.fill_normal(&mut da, 1.0);
    rng.fill_normal(&mut db, 1.0);
    let dot_args = [
        client.buffer_from_host_buffer::<f32>(&da, &[DOT_N, DOT_N], None)?,
        client.buffer_from_host_buffer::<f32>(&db, &[DOT_N, DOT_N], None)?,
    ];
    dot_exe.execute_b(&dot_args)?; // warm-up
    let t0 = Instant::now();
    for _ in 0..DOT_EXECS {
        std::hint::black_box(dot_exe.execute_b(&dot_args)?);
    }
    let dot_secs = t0.elapsed().as_secs_f64();
    let dot_gflops =
        (2.0 * (DOT_N as f64).powi(3) * DOT_EXECS as f64) / dot_secs / 1e9;

    // static plan verification in isolation: compile every manifest
    // artifact once (benches run release, where the verifier is off by
    // default) and time `verify()` on its own, so BENCH_search.json
    // shows what `--verify-plans 1` costs per compiled module
    const VERIFY_REPS: usize = 16;
    let mut verify_exes = Vec::new();
    for spec in rt.manifest().artifacts.values() {
        let proto = xla::HloModuleProto::from_text_file(&dir.join(&spec.file))?;
        verify_exes.push(client.compile(&xla::XlaComputation::from_proto(&proto))?);
    }
    for exe in &verify_exes {
        exe.verify()?; // warm-up, and proof the shipped artifacts are sound
    }
    let t0 = Instant::now();
    for _ in 0..VERIFY_REPS {
        for exe in &verify_exes {
            std::hint::black_box(exe.verify())?;
        }
    }
    let verify_secs = t0.elapsed().as_secs_f64();
    let verify_micros_per_module =
        verify_secs / (VERIFY_REPS * verify_exes.len()) as f64 * 1e6;
    let train_exec_micros = train_secs / TRAIN_EXECS as f64 * 1e6;
    let verify_overhead_vs_train_exec = verify_micros_per_module / train_exec_micros;

    println!(
        "bench search/interpreter_load   {:>10}  (platform `{}`, {} artifacts)",
        common::fmt(load_secs),
        rt.platform(),
        rt.manifest().artifacts.len()
    );
    println!(
        "bench search/interpreter_pred   {:>10}  {:>7.1} execs/s (surrogate_predict, \
         {:.2}x over reference {ref_predict_eps:.1})",
        common::fmt(predict_secs / PREDICT_EXECS as f64),
        predict_eps,
        predict_eps / ref_predict_eps
    );
    println!(
        "bench search/interpreter_train  {:>10}  {:>7.1} execs/s (train_step, \
         {:.2}x over reference {ref_train_eps:.1})",
        common::fmt(train_secs / TRAIN_EXECS as f64),
        train_eps,
        train_eps / ref_train_eps
    );
    println!(
        "bench search/interpreter_dot    {:>10}  {dot_gflops:>7.2} GFLOP/s \
         ({DOT_N}^3 f32 matmul, {} thread(s))",
        common::fmt(dot_secs / DOT_EXECS as f64),
        xla::dot_threads().max(1)
    );
    println!(
        "bench search/interpreter_allocs  fresh {fresh_per_exec:.1}/exec, \
         reused {reused_per_exec:.1}/exec (train_step, warm arena)"
    );
    println!(
        "bench search/interpreter_verify {:>10}  per module \
         ({:.4}x of one train_step exec, {} modules)",
        common::fmt(verify_micros_per_module / 1e6),
        verify_overhead_vs_train_exec,
        verify_exes.len()
    );
    Ok(Json::obj(vec![
        ("platform", Json::Str(rt.platform())),
        ("artifact_dir", Json::Str(dir.display().to_string())),
        ("load_seconds", Json::Num(load_secs)),
        ("surrogate_predict_execs_per_sec", Json::Num(predict_eps)),
        ("train_step_execs_per_sec", Json::Num(train_eps)),
        (
            "reference_surrogate_predict_execs_per_sec",
            Json::Num(ref_predict_eps),
        ),
        ("reference_train_step_execs_per_sec", Json::Num(ref_train_eps)),
        (
            "surrogate_predict_speedup_vs_reference",
            Json::Num(predict_eps / ref_predict_eps),
        ),
        (
            "train_step_speedup_vs_reference",
            Json::Num(train_eps / ref_train_eps),
        ),
        ("dot_general_gflops", Json::Num(dot_gflops)),
        ("train_step_fresh_allocs_per_exec", Json::Num(fresh_per_exec)),
        ("train_step_reused_allocs_per_exec", Json::Num(reused_per_exec)),
        ("verify_micros_per_module", Json::Num(verify_micros_per_module)),
        (
            "verify_overhead_vs_train_exec",
            Json::Num(verify_overhead_vs_train_exec),
        ),
    ]))
}

/// Phase 6a: the per-generation surrogate win — one padded execution
/// per genome (the old per-trial path) vs ⌈N/`SUR_BATCH`⌉ batched
/// executions, same rows, same (untrained but deterministic) weights.
fn bench_surrogate_batching() -> anyhow::Result<Json> {
    let dir = snac_pack::runtime::artifact_dir()
        .ok_or_else(|| anyhow::anyhow!("no artifact/fixture manifest in this tree"))?;
    let rt = Runtime::load(&dir)?;
    let mut rng = Rng::new(42);
    let params = SurrogateParams::init(&mut rng);
    const ROWS: usize = 96;
    let space = SearchSpace::table1();
    let mut feats: Vec<Vec<f32>> = Vec::new();
    while feats.len() < ROWS {
        let f = genome_features(&space.sample(&mut rng), &space, 8, 0.5);
        if !feats.contains(&f) {
            feats.push(f);
        }
    }

    let per_trial = SurrogatePredictor::new(&rt, params.clone());
    let t0 = Instant::now();
    for f in &feats {
        std::hint::black_box(per_trial.predict_batch(std::slice::from_ref(f))?);
    }
    let per_trial_secs = t0.elapsed().as_secs_f64();
    assert_eq!(per_trial.executions(), ROWS);

    let batched = SurrogatePredictor::new(&rt, params.clone());
    let t0 = Instant::now();
    std::hint::black_box(batched.predict_batch(&feats)?);
    let batched_secs = t0.elapsed().as_secs_f64();
    assert_eq!(batched.executions(), ROWS.div_ceil(nn::SUR_BATCH));

    println!(
        "bench search/surrogate_per_trial {:>9}  {:>7.1} rows/s  ({ROWS} executions)",
        common::fmt(per_trial_secs),
        ROWS as f64 / per_trial_secs
    );
    println!(
        "bench search/surrogate_batched  {:>10}  {:>7.1} rows/s  ({} executions, {:.1}x)",
        common::fmt(batched_secs),
        ROWS as f64 / batched_secs,
        batched.executions(),
        per_trial_secs / batched_secs
    );
    Ok(Json::obj(vec![
        ("rows", Json::Num(ROWS as f64)),
        ("per_trial_seconds", Json::Num(per_trial_secs)),
        ("per_trial_executions", Json::Num(per_trial.executions() as f64)),
        ("per_trial_rows_per_sec", Json::Num(ROWS as f64 / per_trial_secs)),
        ("batched_seconds", Json::Num(batched_secs)),
        ("batched_executions", Json::Num(batched.executions() as f64)),
        ("batched_rows_per_sec", Json::Num(ROWS as f64 / batched_secs)),
        ("speedup", Json::Num(per_trial_secs / batched_secs)),
    ]))
}

/// Phase 6b (`serve_load`): sustained `/estimate` throughput and latency
/// quantiles under concurrent clients, one-shot vs keep-alive.
///
/// A warm-up pass fills the engine's estimate memo first, so both
/// measured passes are transport-bound — the keep-alive delta is then
/// purely the saved per-request connection setup, and it must win.
/// Every served value is asserted bit-identical to an in-process
/// `SurrogatePredictor` built from the same weights.
fn bench_serve_load() -> anyhow::Result<Json> {
    let dir = snac_pack::runtime::artifact_dir()
        .ok_or_else(|| anyhow::anyhow!("no artifact/fixture manifest in this tree"))?;
    let rt = Runtime::load(&dir)?;
    let mut rng = Rng::new(4242);
    let params = SurrogateParams::init(&mut rng);
    let predictor = SurrogatePredictor::new(&rt, params.clone());
    let engine = SurrogateEngine::new(
        &predictor,
        EngineConfig {
            deadline: Duration::from_millis(2),
            ..Default::default()
        },
    );
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    let ctx = ServeContext {
        engine: &engine,
        space: &space,
        device: &device,
        bits: 8,
        sparsity: 0.5,
        platform: rt.platform(),
        metrics: ServeMetrics::new(),
    };
    let tuning = ServeTuning::default();
    let listener = std::net::TcpListener::bind("127.0.0.1:0")?;
    let addr = listener.local_addr()?.to_string();

    const CLIENTS: usize = 4;
    const PER_CLIENT: usize = 24;
    const PASSES: usize = 2; // best-of per mode, after the warm-up
    let genomes = distinct_genomes(CLIENTS * PER_CLIENT, 77);
    let bodies: Vec<String> = genomes
        .iter()
        .map(|g| Json::obj(vec![("genome", g.to_json())]).to_string())
        .collect();
    // the bit-identity reference: same weights, separate predictor, so
    // its memo/execution counters never perturb the served engine's
    let reference = SurrogatePredictor::new(&rt, params);
    let expected: Vec<(f64, f64)> = genomes
        .iter()
        .map(|g| {
            let e = reference.predict(g, &space, 8, 0.5).expect("reference predict");
            (e.lut, e.ii_cc)
        })
        .collect();

    let ctx_ref = &ctx;
    let tuning_ref = &tuning;
    let addr_ref = addr.as_str();
    let bodies_ref = bodies.as_slice();
    let expected_ref = expected.as_slice();
    let mut one_shot = (f64::INFINITY, Vec::new());
    let mut keep_alive = (f64::INFINITY, Vec::new());
    let mut shed = 0.0f64;
    std::thread::scope(|s| -> anyhow::Result<()> {
        let server = s.spawn(move || snac_pack::serve::serve(ctx_ref, listener, tuning_ref));
        // drive the clients inside a closure so the shutdown request
        // runs on *every* exit path — otherwise a failed client would
        // leave the accept loop alive and deadlock the scope join
        let mut drive_clients = || -> anyhow::Result<()> {
            let (status, _) = http::request(addr_ref, "GET", "/healthz", None)?;
            anyhow::ensure!(status == 200, "healthz failed");
            // one full pass over the genome set: `keep` picks the client
            // style; returns (wall seconds, sorted per-request ms)
            let run_pass = |keep: bool| -> anyhow::Result<(f64, Vec<f64>)> {
                let t0 = Instant::now();
                let handles: Vec<_> = (0..CLIENTS)
                    .map(|c| {
                        s.spawn(move || -> anyhow::Result<Vec<f64>> {
                            let mut lat = Vec::with_capacity(PER_CLIENT);
                            let mut client = keep.then(|| {
                                http::HttpClient::new(addr_ref, Duration::from_secs(10))
                            });
                            for i in c * PER_CLIENT..(c + 1) * PER_CLIENT {
                                let t = Instant::now();
                                let (status, resp) = match &mut client {
                                    Some(cl) => {
                                        cl.request("POST", "/estimate", Some(&bodies_ref[i]))?
                                    }
                                    None => http::request(
                                        addr_ref,
                                        "POST",
                                        "/estimate",
                                        Some(&bodies_ref[i]),
                                    )?,
                                };
                                lat.push(t.elapsed().as_secs_f64() * 1e3);
                                anyhow::ensure!(status == 200, "estimate failed: {resp}");
                                let j = Json::parse(&resp)
                                    .map_err(|e| anyhow::anyhow!("estimate response: {e}"))?;
                                let lut = j.get("lut").and_then(Json::as_f64);
                                let ii = j.get("ii_cc").and_then(Json::as_f64);
                                anyhow::ensure!(
                                    lut == Some(expected_ref[i].0)
                                        && ii == Some(expected_ref[i].1),
                                    "served estimate diverged from the in-process predictor \
                                     (request {i}: got {lut:?}/{ii:?}, want {:?})",
                                    expected_ref[i]
                                );
                            }
                            Ok(lat)
                        })
                    })
                    .collect();
                let mut all = Vec::new();
                for h in handles {
                    all.extend(h.join().expect("client thread")?);
                }
                let secs = t0.elapsed().as_secs_f64();
                all.sort_by(f64::total_cmp);
                Ok((secs, all))
            };
            run_pass(false)?; // warm-up: fills the estimate memo
            for _ in 0..PASSES {
                let pass = run_pass(false)?;
                if pass.0 < one_shot.0 {
                    one_shot = pass;
                }
                let pass = run_pass(true)?;
                if pass.0 < keep_alive.0 {
                    keep_alive = pass;
                }
            }
            let (status, metrics) = http::request(addr_ref, "GET", "/metrics", None)?;
            anyhow::ensure!(status == 200, "metrics failed: {metrics}");
            let m = Json::parse(&metrics).map_err(|e| anyhow::anyhow!("metrics: {e}"))?;
            let hit_rate = m
                .get("engine")
                .and_then(|e| e.get("memo_hit_rate"))
                .and_then(Json::as_f64)
                .unwrap_or(0.0);
            anyhow::ensure!(hit_rate > 0.5, "memo should be warm, hit rate {hit_rate}");
            shed = m
                .get("connections")
                .and_then(|c| c.get("shed"))
                .and_then(Json::as_f64)
                .unwrap_or(f64::NAN);
            Ok(())
        };
        let clients = drive_clients();
        let shutdown = http::request(addr_ref, "POST", "/shutdown", None);
        let server_result = server.join().expect("server thread");
        clients?;
        let (status, _) = shutdown?;
        anyhow::ensure!(status == 200, "shutdown failed");
        server_result?;
        Ok(())
    })?;

    let requests = CLIENTS * PER_CLIENT;
    let mode = |name: &str, (secs, lat): &(f64, Vec<f64>)| -> Json {
        println!(
            "bench search/serve_{name:<10} {:>10}  {:>7.1} reqs/s  \
             p50 {:.2}ms p99 {:.2}ms  ({CLIENTS} clients)",
            common::fmt(*secs),
            requests as f64 / secs,
            sorted_quantile(lat, 0.50),
            sorted_quantile(lat, 0.99),
        );
        Json::obj(vec![
            ("seconds", Json::Num(*secs)),
            ("requests_per_sec", Json::Num(requests as f64 / secs)),
            ("p50_ms", Json::Num(sorted_quantile(lat, 0.50))),
            ("p99_ms", Json::Num(sorted_quantile(lat, 0.99))),
        ])
    };
    let one_shot_json = mode("one_shot", &one_shot);
    let keep_alive_json = mode("keep_alive", &keep_alive);
    let speedup = one_shot.0 / keep_alive.0;
    println!(
        "bench search/serve_keepalive_speedup  {speedup:.2}x over one-shot \
         ({} flushes, {} executions, {shed} shed)",
        engine.flushes(),
        predictor.executions()
    );
    // memo-warm + loopback: the only difference between the modes is
    // per-request connection setup, so persistent connections must win
    anyhow::ensure!(
        keep_alive.0 < one_shot.0,
        "keep-alive ({:.4}s) must beat one-shot ({:.4}s) on a memo-warm engine",
        keep_alive.0,
        one_shot.0
    );
    Ok(Json::obj(vec![
        ("requests", Json::Num(requests as f64)),
        ("clients", Json::Num(CLIENTS as f64)),
        ("one_shot", one_shot_json),
        ("keep_alive", keep_alive_json),
        ("keep_alive_speedup", Json::Num(speedup)),
        ("shed", Json::Num(shed)),
        ("flushes", Json::Num(engine.flushes() as f64)),
        ("executions", Json::Num(predictor.executions() as f64)),
    ]))
}

fn main() -> anyhow::Result<()> {
    println!("== SNAC-Pack search-throughput bench ==");
    println!(
        "budget: {TRIALS} trials, population {POPULATION}, {SIM_PASSES} simulator passes/trial"
    );

    // ---- phase 1: worker scaling ----
    let mut results = Vec::new();
    let mut serial_genomes: Option<Vec<Genome>> = None;
    let mut serial_secs = 0.0f64;
    let mut untraced4_secs = f64::NAN;
    for workers in [1usize, 2, 4] {
        // warm-up + best-of-3, matching the in-repo harness style
        run(workers);
        let mut samples: Vec<(SearchOutcome, f64)> = (0..3).map(|_| run(workers)).collect();
        samples.sort_by(|a, b| a.1.total_cmp(&b.1));
        let (outcome, secs) = samples.remove(0);
        let genomes: Vec<Genome> = outcome.records.iter().map(|r| r.genome.clone()).collect();
        match &serial_genomes {
            None => {
                serial_genomes = Some(genomes);
                serial_secs = secs;
            }
            Some(expected) => assert_eq!(
                expected, &genomes,
                "worker count must not change the trial stream"
            ),
        }
        if workers == 4 {
            untraced4_secs = secs;
        }
        let tps = TRIALS as f64 / secs;
        let speedup = serial_secs / secs;
        println!(
            "bench search/workers_{workers:<2} {:>10}  {tps:>7.1} trials/s  \
             speedup {speedup:>5.2}x  ({} trained, {} cache hits)",
            common::fmt(secs),
            outcome.evaluations,
            outcome.cache_hits
        );
        results.push(Json::obj(vec![
            ("workers", Json::Num(workers as f64)),
            ("seconds", Json::Num(secs)),
            ("trials_per_sec", Json::Num(tps)),
            ("speedup_vs_serial", Json::Num(speedup)),
            ("evaluations", Json::Num(outcome.evaluations as f64)),
            ("cache_hits", Json::Num(outcome.cache_hits as f64)),
        ]));
    }
    println!("determinism: trial streams identical across worker counts");

    // ---- phase 1b: tracing overhead ----
    // The same 4-worker budget with the tracer live: generation, trial,
    // and dispatch spans all record. The trial stream must stay
    // bit-identical and the throughput cost marginal (CI asserts the
    // recorded overhead_pct stays under its budget).
    telemetry::init(None);
    run(4); // traced warm-up
    telemetry::drain();
    let mut traced_secs = f64::INFINITY;
    let mut traced_spans = 0usize;
    for _ in 0..3 {
        let (outcome, secs) = run(4);
        let spans = telemetry::drain().len();
        let genomes: Vec<Genome> = outcome.records.iter().map(|r| r.genome.clone()).collect();
        assert_eq!(
            serial_genomes.as_ref().expect("phase 1 ran"),
            &genomes,
            "tracing must not change the trial stream"
        );
        if secs < traced_secs {
            traced_secs = secs;
            traced_spans = spans;
        }
    }
    telemetry::disable();
    let overhead_pct = (traced_secs - untraced4_secs) / untraced4_secs * 100.0;
    println!(
        "bench search/tracing_overhead   {:>10}  {overhead_pct:>+6.2}% vs untraced  \
         ({traced_spans} spans/run)",
        common::fmt(traced_secs)
    );
    println!("determinism: traced trial stream identical to untraced");
    let tracing_overhead = Json::obj(vec![
        ("workers", Json::Num(4.0)),
        ("untraced_seconds", Json::Num(untraced4_secs)),
        ("traced_seconds", Json::Num(traced_secs)),
        ("overhead_pct", Json::Num(overhead_pct)),
        ("spans_per_run", Json::Num(traced_spans as f64)),
    ]);

    // ---- phase 2: streaming vs chunked dispatch under cost skew ----
    let skew_genomes = distinct_genomes(SKEW_TRIALS, 23);
    let mut chunked_secs = f64::INFINITY;
    let mut chunked_accs = Vec::new();
    let mut streaming_secs = f64::INFINITY;
    let mut streaming_accs = Vec::new();
    for _ in 0..3 {
        // fresh pools each run: both strategies start from an empty cache
        let pool = ParallelEvaluator::new(skewed_trainer(), SKEW_WORKERS);
        let t0 = Instant::now();
        chunked_accs = dispatch_chunked(&pool, requests(&skew_genomes, 5));
        chunked_secs = chunked_secs.min(t0.elapsed().as_secs_f64());

        let pool = ParallelEvaluator::new(skewed_trainer(), SKEW_WORKERS);
        let t0 = Instant::now();
        streaming_accs = dispatch_streaming(&pool, requests(&skew_genomes, 5));
        streaming_secs = streaming_secs.min(t0.elapsed().as_secs_f64());
    }
    assert_eq!(
        chunked_accs, streaming_accs,
        "dispatch strategy must not change trial results"
    );
    println!(
        "bench search/dispatch_chunked   {:>10}  ({SKEW_TRIALS} skewed trials, {SKEW_WORKERS} workers)",
        common::fmt(chunked_secs)
    );
    println!(
        "bench search/dispatch_streaming {:>10}  (speedup {:.2}x over chunk barriers)",
        common::fmt(streaming_secs),
        chunked_secs / streaming_secs
    );
    // Correctness gate with generous headroom for noisy shared CI
    // runners: streaming genuinely beats chunk barriers under this skew,
    // so 1.25x only trips on a real dispatch regression. The precise
    // ratio is recorded in BENCH_search.json for trajectory tracking.
    assert!(
        streaming_secs <= chunked_secs * 1.25,
        "streaming dispatch must not be slower than the chunked path \
         (streaming {streaming_secs:.3}s vs chunked {chunked_secs:.3}s)"
    );

    // ---- phase 3: cache persistence across runs ----
    let cache_dir = std::env::temp_dir().join("snac_bench_cache");
    std::fs::create_dir_all(&cache_dir)?;
    let cache_path = cache_dir.join("BENCH_eval_cache.json");
    let _ = std::fs::remove_file(&cache_path);
    let space = SearchSpace::table1();
    let load = |path: &Path| EvalCache::load(path, &space, "bench");
    let (cold, cold_secs) = run_with_cache(4, load(&cache_path));
    let (warm, warm_secs) = run_with_cache(4, load(&cache_path));
    assert_eq!(warm.evaluations, 0, "second run must retrain nothing");
    assert_eq!(warm.cache_hits, TRIALS, "every trial served from the snapshot");
    assert_eq!(warm.cache_restored, cold.evaluations);
    let cold_genomes: Vec<&Genome> = cold.records.iter().map(|r| &r.genome).collect();
    let warm_genomes: Vec<&Genome> = warm.records.iter().map(|r| &r.genome).collect();
    assert_eq!(cold_genomes, warm_genomes, "identical trial records across runs");
    println!(
        "bench search/cache_cold         {:>10}  ({} trained)",
        common::fmt(cold_secs),
        cold.evaluations
    );
    println!(
        "bench search/cache_warm         {:>10}  (0 trained, {} cache hits, {} restored)",
        common::fmt(warm_secs),
        warm.cache_hits,
        warm.cache_restored
    );

    // ---- phase 4: interpreter execute throughput ----
    let interpreter = bench_interpreter()?;

    // ---- phase 5: sharded dispatch over the file-based work queue ----
    let serial_genomes = serial_genomes.expect("phase 1 ran");
    let mut sharded_results = Vec::new();
    let mut fs_2x2_secs = f64::NAN;
    for (shards, workers) in [(2usize, 2usize), (4, 4)] {
        let (outcome, secs) = run_sharded(Transport::Fs, shards, workers);
        let genomes: Vec<Genome> = outcome.records.iter().map(|r| r.genome.clone()).collect();
        assert_eq!(
            serial_genomes, genomes,
            "sharded dispatch must not change the trial stream"
        );
        if shards == 2 {
            fs_2x2_secs = secs;
        }
        let tps = TRIALS as f64 / secs;
        println!(
            "bench search/sharded_{shards}x{workers:<2}  {:>10}  {tps:>7.1} trials/s  \
             ({} trained, {} cache hits)",
            common::fmt(secs),
            outcome.evaluations,
            outcome.cache_hits
        );
        sharded_results.push(Json::obj(vec![
            ("shards", Json::Num(shards as f64)),
            ("workers", Json::Num(workers as f64)),
            ("seconds", Json::Num(secs)),
            ("trials_per_sec", Json::Num(tps)),
            ("evaluations", Json::Num(outcome.evaluations as f64)),
            ("speedup_vs_serial", Json::Num(serial_secs / secs)),
        ]));
    }
    println!("determinism: sharded trial streams identical to the in-process pool");

    // ---- phase 5b: the same budget over the TCP transport ----
    // Same shard protocol, different medium: HTTP task claims over
    // loopback instead of rename-based files. The trial stream must be
    // bit-identical; the throughput delta is the wire cost.
    let (tcp_outcome, tcp_secs) = run_sharded(Transport::Tcp, 2, 2);
    let tcp_genomes: Vec<Genome> =
        tcp_outcome.records.iter().map(|r| r.genome.clone()).collect();
    assert_eq!(
        serial_genomes, tcp_genomes,
        "TCP dispatch must not change the trial stream"
    );
    println!(
        "bench search/transport_tcp_2x2  {:>10}  {:>7.1} trials/s  \
         (fs {:.1} trials/s over the same 2x2 budget)",
        common::fmt(tcp_secs),
        TRIALS as f64 / tcp_secs,
        TRIALS as f64 / fs_2x2_secs
    );
    println!("determinism: TCP trial stream identical to the in-process pool");
    let transport_throughput = Json::obj(vec![
        ("shards", Json::Num(2.0)),
        ("workers", Json::Num(2.0)),
        ("fs_seconds", Json::Num(fs_2x2_secs)),
        ("fs_trials_per_sec", Json::Num(TRIALS as f64 / fs_2x2_secs)),
        ("tcp_seconds", Json::Num(tcp_secs)),
        ("tcp_trials_per_sec", Json::Num(TRIALS as f64 / tcp_secs)),
    ]);

    // ---- phase 6: surrogate batching + the estimation service ----
    let surrogate_batching = bench_surrogate_batching()?;
    let serve_load = bench_serve_load()?;

    let report = Json::obj(vec![
        ("bench", Json::Str("search_throughput".to_string())),
        ("interpreter", interpreter),
        (
            "budget",
            Json::obj(vec![
                ("trials", Json::Num(TRIALS as f64)),
                ("population", Json::Num(POPULATION as f64)),
                ("sim_passes_per_trial", Json::Num(SIM_PASSES as f64)),
                ("seed", Json::Num(SEED as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
        ("tracing_overhead", tracing_overhead),
        (
            "streaming_vs_chunked",
            Json::obj(vec![
                ("trials", Json::Num(SKEW_TRIALS as f64)),
                ("workers", Json::Num(SKEW_WORKERS as f64)),
                ("chunked_seconds", Json::Num(chunked_secs)),
                ("streaming_seconds", Json::Num(streaming_secs)),
                (
                    "speedup",
                    Json::Num(chunked_secs / streaming_secs),
                ),
            ]),
        ),
        (
            "cache_persistence",
            Json::obj(vec![
                ("cold_seconds", Json::Num(cold_secs)),
                ("warm_seconds", Json::Num(warm_secs)),
                ("cold_evaluations", Json::Num(cold.evaluations as f64)),
                ("warm_evaluations", Json::Num(warm.evaluations as f64)),
                ("warm_cache_hits", Json::Num(warm.cache_hits as f64)),
                ("warm_cache_restored", Json::Num(warm.cache_restored as f64)),
            ]),
        ),
        ("sharded", Json::Arr(sharded_results)),
        ("transport_throughput", transport_throughput),
        ("surrogate_batching", surrogate_batching),
        ("serve_load", serve_load),
    ]);
    std::fs::write("BENCH_search.json", report.to_string())?;
    println!("wrote BENCH_search.json");
    Ok(())
}
