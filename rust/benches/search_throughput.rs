//! Bench: global-search trial throughput vs evaluation worker count.
//!
//! Drives the real search machinery — NSGA-II, the generation scheduler,
//! the genome-keyed evaluation cache — through `global_search_with` with a
//! simulated trial evaluator whose cost is CPU-bound work in the HLS
//! synthesis simulator (no runtime artifacts required, so this runs
//! anywhere and stays comparable across PRs). Verifies that every worker
//! count produces the identical trial stream, then reports trials/sec at
//! `workers ∈ {1, 2, 4}` and writes `BENCH_search.json` for the perf
//! trajectory.
//!
//! Runs with `progress: None` (whole-generation batches); production runs
//! attach a progress sink, which dispatches in worker-sized chunks for
//! liveness — so these numbers are an upper bound on pipeline throughput.

mod common;

use std::time::Instant;

use snac_pack::coordinator::{global_search_with, SearchLoopConfig, SearchOutcome};
use snac_pack::eval::{ParallelEvaluator, TrialEvaluation, TrialEvaluator};
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::{Genome, SearchSpace};
use snac_pack::search::Nsga2Config;
use snac_pack::util::{Json, Rng};

const TRIALS: usize = 48;
const POPULATION: usize = 8;
const SEED: u64 = 17;
/// Simulator passes per trial — sized so one trial costs milliseconds,
/// like a (very) small training run, dwarfing scheduling overhead.
const SIM_PASSES: usize = 300;

/// Stand-in for the train-and-score path: deterministic accuracy with a
/// real size/accuracy trade-off, priced by a CPU-bound simulator loop.
struct SimulatedTrainer {
    space: SearchSpace,
    hls: HlsConfig,
    device: FpgaDevice,
}

impl TrialEvaluator for SimulatedTrainer {
    fn evaluate(&self, genome: &Genome, rng: &mut Rng) -> anyhow::Result<TrialEvaluation> {
        let t0 = Instant::now();
        let mut lut_sum = 0u64;
        for pass in 0..SIM_PASSES {
            let sparsity = (pass % 8) as f64 / 16.0;
            let spec = NetworkSpec::from_genome(genome, &self.space, 8, sparsity);
            lut_sum += std::hint::black_box(synthesize(&spec, &self.hls, &self.device)).lut;
        }
        std::hint::black_box(lut_sum);
        let weights = genome.num_weights(&self.space) as f64;
        let accuracy = (1.0 - (-weights / 4000.0).exp()) * (0.9 + 0.1 * rng.uniform());
        Ok(TrialEvaluation {
            accuracy,
            bops: weights,
            est_avg_resources: None,
            est_clock_cycles: None,
            objectives: vec![-accuracy, weights],
            train_seconds: t0.elapsed().as_secs_f64(),
        })
    }
}

fn run(workers: usize) -> (SearchOutcome, f64, usize, usize) {
    let space = SearchSpace::table1();
    let pool = ParallelEvaluator::new(
        SimulatedTrainer {
            space: space.clone(),
            hls: HlsConfig::default(),
            device: FpgaDevice::vu13p(),
        },
        workers,
    );
    let t0 = Instant::now();
    let outcome = global_search_with(
        &pool,
        &space,
        SearchLoopConfig {
            nsga2: Nsga2Config {
                population: POPULATION,
                ..Default::default()
            },
            trials: TRIALS,
            seed: SEED,
            accuracy_threshold: 0.0,
            progress: None,
        },
    )
    .expect("simulated search");
    let secs = t0.elapsed().as_secs_f64();
    (outcome, secs, pool.evaluations(), pool.cache_hits())
}

fn main() -> anyhow::Result<()> {
    println!("== SNAC-Pack search-throughput bench ==");
    println!(
        "budget: {TRIALS} trials, population {POPULATION}, {SIM_PASSES} simulator passes/trial"
    );

    let mut results = Vec::new();
    let mut serial_genomes: Option<Vec<Genome>> = None;
    let mut serial_secs = 0.0f64;
    for workers in [1usize, 2, 4] {
        // warm-up + best-of-3, matching the in-repo harness style
        run(workers);
        let mut samples: Vec<(SearchOutcome, f64, usize, usize)> =
            (0..3).map(|_| run(workers)).collect();
        samples.sort_by(|a, b| a.1.total_cmp(&b.1));
        let (outcome, secs, evaluations, cache_hits) = samples.remove(0);
        let genomes: Vec<Genome> = outcome.records.iter().map(|r| r.genome.clone()).collect();
        match &serial_genomes {
            None => {
                serial_genomes = Some(genomes);
                serial_secs = secs;
            }
            Some(expected) => assert_eq!(
                expected, &genomes,
                "worker count must not change the trial stream"
            ),
        }
        let tps = TRIALS as f64 / secs;
        let speedup = serial_secs / secs;
        println!(
            "bench search/workers_{workers:<2} {:>10}  {tps:>7.1} trials/s  \
             speedup {speedup:>5.2}x  ({evaluations} trained, {cache_hits} cache hits)",
            common::fmt(secs)
        );
        results.push(Json::obj(vec![
            ("workers", Json::Num(workers as f64)),
            ("seconds", Json::Num(secs)),
            ("trials_per_sec", Json::Num(tps)),
            ("speedup_vs_serial", Json::Num(speedup)),
            ("evaluations", Json::Num(evaluations as f64)),
            ("cache_hits", Json::Num(cache_hits as f64)),
        ]));
    }
    println!("determinism: trial streams identical across worker counts");

    let report = Json::obj(vec![
        ("bench", Json::Str("search_throughput".to_string())),
        (
            "budget",
            Json::obj(vec![
                ("trials", Json::Num(TRIALS as f64)),
                ("population", Json::Num(POPULATION as f64)),
                ("sim_passes_per_trial", Json::Num(SIM_PASSES as f64)),
                ("seed", Json::Num(SEED as f64)),
            ]),
        ),
        ("results", Json::Arr(results)),
    ]);
    std::fs::write("BENCH_search.json", report.to_string())?;
    println!("wrote BENCH_search.json");
    Ok(())
}
