//! Minimal bench harness (criterion is unavailable offline — see
//! Cargo.toml): warmup + timed iterations with mean/min/p50 reporting.

// each bench target compiles this module separately and uses a subset
#![allow(dead_code)]

use std::time::Instant;

/// Time `f` for `iters` iterations after `warmup` untimed runs, printing a
/// criterion-style line. Returns mean seconds.
pub fn bench<T>(name: &str, warmup: usize, iters: usize, mut f: impl FnMut() -> T) -> f64 {
    for _ in 0..warmup {
        std::hint::black_box(f());
    }
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t0 = Instant::now();
        std::hint::black_box(f());
        samples.push(t0.elapsed().as_secs_f64());
    }
    samples.sort_by(f64::total_cmp);
    let mean = samples.iter().sum::<f64>() / samples.len() as f64;
    let p50 = samples[samples.len() / 2];
    let min = samples[0];
    println!(
        "bench {name:<40} mean {:>10}  p50 {:>10}  min {:>10}  ({iters} iters)",
        fmt(mean),
        fmt(p50),
        fmt(min)
    );
    mean
}

/// Human-readable seconds.
pub fn fmt(secs: f64) -> String {
    if secs >= 1.0 {
        format!("{secs:.2}s")
    } else if secs >= 1e-3 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.1}µs", secs * 1e6)
    }
}

/// Throughput helper.
pub fn per_sec(count: usize, secs: f64) -> String {
    format!("{:.0}/s", count as f64 / secs)
}
