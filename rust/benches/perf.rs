//! Perf micro-benchmarks: the hot paths behind EXPERIMENTS.md §Perf.
//!
//! * `train_step` execution (the dominant cost: one fused fwd+bwd+Adam HLO
//!   call per minibatch);
//! * `eval_step` execution;
//! * surrogate prediction (priced once per candidate);
//! * literal packing overhead (host → PJRT buffer);
//! * NSGA-II generation machinery (sort + crowding + breeding);
//! * HLS simulator throughput;
//! * jet generation throughput.

mod common;

use snac_pack::data::{Dataset, Split};
use snac_pack::hls::{synthesize, FpgaDevice, HlsConfig, NetworkSpec};
use snac_pack::nn::{PruneMasks, SearchSpace, SupernetInputs, BATCH};
use snac_pack::runtime::Runtime;
use snac_pack::search::{EvaluatedIndividual, Nsga2, Nsga2Config};
use snac_pack::surrogate::{train_surrogate, SurrogatePredictor, SurrogateTrainConfig};
use snac_pack::trainer::{TrainConfig, Trainer};
use snac_pack::util::Rng;

fn main() -> anyhow::Result<()> {
    println!("== SNAC-Pack perf benches ==");
    let space = SearchSpace::table1();
    let device = FpgaDevice::vu13p();
    let hls = HlsConfig::default();

    // ---------- pure-rust paths ----------
    let mut rng = Rng::new(1);
    common::bench("perf/jet_generation_1k", 2, 20, || {
        Dataset::generate(1000, 0, 0, rng.next_u64())
    });

    let genomes: Vec<_> = (0..1000).map(|_| space.sample(&mut rng)).collect();
    common::bench("perf/hls_synthesize_1k", 2, 20, || {
        genomes
            .iter()
            .map(|g| synthesize(&NetworkSpec::from_genome(g, &space, 8, 0.5), &hls, &device).lut)
            .sum::<u64>()
    });

    let pts: Vec<EvaluatedIndividual> = genomes
        .iter()
        .take(100)
        .map(|g| EvaluatedIndividual {
            genome: g.clone(),
            objectives: vec![
                -(g.num_weights(&space) as f64 / 20000.0).tanh(),
                g.num_weights(&space) as f64,
                g.n_layers as f64,
            ],
        })
        .collect();
    common::bench("perf/nsga2_generation_pop100", 2, 50, || {
        let mut engine = Nsga2::new(
            space.clone(),
            Nsga2Config {
                population: 100,
                ..Default::default()
            },
        );
        let mut r = Rng::new(7);
        engine.next_generation(pts.clone(), &mut r)
    });

    // ---------- runtime paths ----------
    let rt = Runtime::load(std::path::Path::new("artifacts"))?;
    let ds = Dataset::generate(BATCH * 4, 512, 512, 7);
    let trainer = Trainer::new(&rt, &ds);
    let genome = space.baseline();
    let inputs = SupernetInputs::compile(&genome, &space);
    let prune = PruneMasks::ones();
    let cfg = TrainConfig {
        epochs: 1,
        ..Default::default()
    };
    let mut model = trainer.init_model(&mut rng);

    // one epoch = 4 train_step executions (batch 128)
    let mean = common::bench("perf/train_epoch_4steps_b128", 1, 15, || {
        trainer
            .train(&mut model, &inputs, &prune, &cfg, &mut rng)
            .unwrap()
    });
    println!(
        "  → per train_step: {}  ({} jets/s)",
        common::fmt(mean / 4.0),
        common::per_sec(4 * BATCH, mean)
    );

    let mean = common::bench("perf/eval_512_jets", 1, 15, || {
        trainer
            .evaluate(&model, &inputs, &prune, &cfg, Split::Val)
            .unwrap()
    });
    println!("  → {} jets/s", common::per_sec(512, mean));

    let (sp, _) = train_surrogate(
        &rt,
        &space,
        &SurrogateTrainConfig {
            dataset_size: 256,
            epochs: 3,
            ..Default::default()
        },
        &hls,
        &device,
    )?;
    let sur = SurrogatePredictor::new(&rt, sp);
    let fresh: Vec<_> = (0..64).map(|_| space.sample(&mut rng)).collect();
    let mean = common::bench("perf/surrogate_predict_64_uncached", 1, 10, || {
        // vary sparsity to bust the cache: measures the true predict path
        let s = rng.uniform();
        fresh
            .iter()
            .map(|g| sur.predict(g, &space, 8, s).unwrap().lut)
            .sum::<f64>()
    });
    println!("  → {} candidates/s", common::per_sec(64, mean));

    common::bench("perf/surrogate_predict_cached", 1, 50, || {
        fresh
            .iter()
            .map(|g| sur.predict(g, &space, 8, 0.5).unwrap().lut)
            .sum::<f64>()
    });
    Ok(())
}
