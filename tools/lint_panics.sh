#!/usr/bin/env bash
# Panic-lint ratchet: non-test library code must not grow new panicking
# call sites (`unwrap()`, `expect(`, `panic!(`, `unreachable!(`).
#
# The recorded baseline (tools/panic_baseline.txt) is the current count;
# this script fails if the count *increases* and asks you to lower the
# baseline when it decreases, so the number only ratchets down. Test
# code is exempt: counting stops at the first `#[cfg(test)]` in each
# file (the repo convention keeps the test module last), and files under
# tests/ or benches/ are never scanned.
#
# Usage: tools/lint_panics.sh            # check against the baseline
#        tools/lint_panics.sh --counts   # print the per-file breakdown
set -euo pipefail

cd "$(dirname "$0")/.."
baseline_file="tools/panic_baseline.txt"
pattern='\.unwrap\(\)|\.expect\(|panic!\(|unreachable!\('

total=0
breakdown=""
for f in $(find rust/src rust/xla/src -name '*.rs' | sort); do
  # strip everything from the first `#[cfg(test)]` onward, then count
  n=$(awk '/^[[:space:]]*#\[cfg\(test\)\]/{exit} {print}' "$f" \
    | grep -cE "$pattern" || true)
  total=$((total + n))
  if [ "$n" -gt 0 ]; then
    breakdown="${breakdown}  ${n}	${f}
"
  fi
done

if [ "${1:-}" = "--counts" ]; then
  printf 'panic-lint: %d panicking call sites in non-test library code\n%s' \
    "$total" "$breakdown"
  exit 0
fi

baseline=$(cat "$baseline_file")
if [ "$total" -gt "$baseline" ]; then
  echo "panic-lint FAILED: $total panicking call sites in non-test library" >&2
  echo "code, baseline is $baseline. New library code must propagate typed" >&2
  echo "errors (anyhow::Result / PlanVerifyError) instead of panicking." >&2
  printf 'Per-file counts:\n%s' "$breakdown" >&2
  exit 1
fi
if [ "$total" -lt "$baseline" ]; then
  echo "panic-lint: count dropped to $total (baseline $baseline) — nice!" >&2
  echo "Ratchet it: echo $total > $baseline_file" >&2
  exit 1
fi
echo "panic-lint ok: $total panicking call sites (== baseline)"
